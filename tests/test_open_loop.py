"""Open-loop serving core: front-end arrivals + overlapped step loop.

The acceptance contract of the serving split (docs/serving.md):

* **compat bit-parity** -- ``run(overlap=True)`` (pipelined dispatch),
  ``run(overlap=False)`` (synchronous reference) and independent
  ``generate`` calls emit identical token streams, across weight
  stores, KV dtypes, attention patterns and kernels;
* **open loop is invisible to the numerics** -- a request arriving
  *mid-run* (virtual clock) joins the running batch and still matches
  its single-request oracle; all-at-once ``serve`` equals ``run``;
* **SLO shedding** is reported, never silent: a dropped request shows
  up in ``stats.shed`` with an empty stream, and survivors keep parity;
* **streaming**: ``on_token`` callbacks fire in token order and carry
  exactly the final output stream;
* **jit-variant boundedness survives the split** -- arrival pattern
  (staggered vs all-at-once) cannot change the ``model_step`` trace
  count, and the batched device sampler adds at most two shapes.
"""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import LM
from repro.quant.policy import QuantPolicy
from repro.serve import FrontEnd, Request, ServeEngine

KEY = jax.random.PRNGKey(0)
MIXED = [(3, 5), (7, 4), (5, 6), (9, 3), (2, 5), (6, 4)]


def _requests(vocab, shapes, seed=3):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, vocab, size=s).astype(np.int32), n)
            for s, n in shapes]


def _engine(arch_id, **kw):
    cfg = ARCHS[arch_id].smoke
    model = LM(cfg)
    params = model.init(KEY)
    return cfg, ServeEngine(model, params, **kw)


class TickClock:
    """Deterministic virtual clock: every reading advances a small tick
    (the loop makes a few readings per step, so steps take 'time'),
    ``sleep`` jumps the full nap.  Arrival-dependent behaviour becomes
    reproducible -- no wall-clock flake."""

    def __init__(self, tick=1e-3):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        self.t += self.tick
        return self.t

    def sleep(self, dt):
        self.t += max(dt, self.tick)


def _vclock_frontend(**kw):
    clk = TickClock()
    return FrontEnd(clock=clk, sleep=clk.sleep, **kw), clk


# ----------------------------------------------------- front-end unit tests
def test_frontend_pump_releases_in_arrival_order():
    from repro.serve import PageAllocator, Scheduler
    fe, clk = _vclock_frontend()
    sched = Scheduler(2, 4, 4, PageAllocator(16))
    toks = np.arange(3, dtype=np.int32)
    fe.submit((toks, 2), at=5.0)
    fe.submit((toks, 2), at=2.0)
    now, released = fe.pump(sched)          # t ~ a few ticks: nothing due
    assert released == [] and fe.n_scheduled == 2
    clk.sleep(2.0)
    _, released = fe.pump(sched)
    assert [r.rid for r in released] == [1]  # the at=2.0 arrival only
    clk.sleep(3.0)
    _, released = fe.pump(sched)
    assert [r.rid for r in released] == [0]
    assert fe.n_scheduled == 0 and fe.n_submitted == 2


def test_frontend_max_queue_rejects_at_submit():
    fe, _ = _vclock_frontend(max_queue=1)
    toks = np.arange(3, dtype=np.int32)
    a = fe.submit((toks, 2))
    b = fe.submit((toks, 2))                 # backlog full: shed immediately
    assert fe.shed == [b.rid] and a.rid not in fe.shed
    assert fe.n_scheduled == 1 and fe.n_submitted == 2


# ------------------------------------------------- compat bit-parity matrix
def _mixed_policy(model, seed=0):
    graph = model.graph(seq_len=4, batch=2)
    policy = QuantPolicy.uniform(graph, 4.0)
    rng = np.random.default_rng(seed)
    for l in graph.layers:
        policy.weight_bits[l.name] = rng.choice(
            [2, 3, 4, 4, 8], size=l.n_groups).astype(np.float32)
    return graph, policy


@pytest.mark.parametrize("cell", [
    "dense_fp",
    "window_int8_fake",
    "ref_fp",
    # packed matmuls run in Pallas interpret mode on CPU: correct but slow
    pytest.param("window_int8_packed", marks=pytest.mark.slow),
])
def test_run_overlap_matrix_matches_sync_and_generate(cell):
    """The pipelined back-end is bit-invisible: overlap on/off/oracle
    agree across the compat matrix (weight store x KV dtype x attention
    pattern x kernel impl), greedy and sampled lanes alike."""
    if cell == "dense_fp":
        cfg, eng = _engine("internlm2-20b", max_len=32)
    elif cell == "ref_fp":
        cfg, eng = _engine("internlm2-20b", max_len=32, attn_impl="ref")
    else:
        cfg = ARCHS["gemma2-2b"].smoke
        model = LM(cfg)
        params = model.init(KEY)
        graph, policy = _mixed_policy(model)
        store = "packed" if cell == "window_int8_packed" else "fake"
        eng = ServeEngine(model, params, policy=policy, graph=graph,
                          max_len=32, weight_store=store, kv_bits=8)
    reqs = _requests(cfg.vocab, MIXED, seed=11)
    reqs[1] = ({"tokens": reqs[1][0], "n_new": reqs[1][1],
                "temperature": 0.8, "seed": 7})
    on = eng.run(reqs, page_size=4, max_slots=4, overlap=True)
    off = eng.run(reqs, page_size=4, max_slots=4, overlap=False)
    assert on["stats"].overlapped and not off["stats"].overlapped
    for i, (a, b) in enumerate(zip(on["outputs"], off["outputs"])):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")
    for i, r in enumerate(reqs):
        toks, n, t, s = ((r["tokens"], r["n_new"], r["temperature"],
                          r["seed"]) if isinstance(r, dict)
                         else (r[0], r[1], 0.0, 0))
        ref = eng.generate(toks[None], n, temperature=t, seed=s)["tokens"][0]
        np.testing.assert_array_equal(on["outputs"][i], ref,
                                      err_msg=f"request {i} vs oracle")


def test_serve_all_at_once_equals_run():
    """run() is the degenerate open loop: pre-submitting every request to
    a FrontEnd and draining serve() reproduces run() stream-for-stream."""
    cfg, eng = _engine("internlm2-20b", max_len=32)
    reqs = _requests(cfg.vocab, MIXED, seed=5)
    ref = eng.run(reqs, page_size=4, max_slots=4)
    fe = FrontEnd()
    rids = [fe.submit(r).rid for r in reqs]
    res = eng.serve(fe, page_size=4, max_slots=4)
    assert res["shed"] == []
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(res["outputs"][rid],
                                      ref["outputs"][i],
                                      err_msg=f"request {i}")
    assert res["stats"].n_requests == len(reqs)


# ------------------------------------------------- open-loop arrival tests
def test_mid_run_arrival_joins_batch_and_streams_in_order():
    """A request arriving while the loop is decoding is admitted into the
    running batch, matches its single-request oracle, and its stream
    callbacks fire in token order interleaved with the earlier stream."""
    cfg, eng = _engine("internlm2-20b", max_len=32)
    fe, clk = _vclock_frontend()
    rng = np.random.default_rng(9)
    prompt_a = rng.integers(0, cfg.vocab, size=3).astype(np.int32)
    prompt_b = rng.integers(0, cfg.vocab, size=4).astype(np.int32)
    events = []

    def cb(rid, idx, tok):
        events.append((rid, idx, tok))

    a = fe.submit((prompt_a, 10), on_token=cb)
    # ~4 clock ticks per step: t=0.01 lands mid-decode of request a
    b = fe.submit((prompt_b, 4), at=0.01, on_token=cb)
    res = eng.serve(fe, page_size=4, max_slots=4)
    stats = res["stats"]
    assert res["shed"] == [] and stats.n_shed == 0
    for req, prompt, n in ((a, prompt_a, 10), (b, prompt_b, 4)):
        ref = eng.generate(prompt[None], n)["tokens"][0]
        np.testing.assert_array_equal(res["outputs"][req.rid], ref,
                                      err_msg=f"rid {req.rid}")
    # b really arrived mid-run: a's stream was still live at b's first token
    b_events = [e for e in events if e[0] == b.rid]
    a_events = [e for e in events if e[0] == a.rid]
    assert events.index(a_events[-1]) > events.index(b_events[0])
    # callbacks fire in token order and carry the final stream exactly
    for req in (a, b):
        mine = [e for e in events if e[0] == req.rid]
        assert [idx for _, idx, _ in mine] == list(range(len(mine)))
        np.testing.assert_array_equal([t for _, _, t in mine],
                                      res["outputs"][req.rid])
    # open-loop latency stats: arrival-relative, populated per request
    for rid in (a.rid, b.rid):
        assert stats.queue_wait_s[rid] >= 0.0
        assert stats.ttft_s[rid] > 0.0
        assert stats.e2e_s[rid] >= stats.ttft_s[rid]
    assert b.rid in stats.queue_wait_s
    assert len(stats.itl_s) == (10 - 1) + (4 - 1)
    for pcts in (stats.queue_wait_percentiles(), stats.e2e_percentiles(),
                 stats.itl_percentiles()):
        assert set(pcts) == {50, 99}
    assert stats.overlapped


def test_queue_slo_sheds_waiter_and_reports_it():
    """With one slot occupied for many steps, a queued request blows its
    queue SLO: it is shed (reported in stats.shed + empty stream), the
    running request never notices, and admitted requests are exempt."""
    cfg, eng = _engine("internlm2-20b", max_len=32)
    fe, clk = _vclock_frontend(queue_slo_s=0.004)
    rng = np.random.default_rng(13)
    prompt_a = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
    prompt_b = rng.integers(0, cfg.vocab, size=4).astype(np.int32)
    a = fe.submit((prompt_a, 12))
    b = fe.submit((prompt_b, 4))
    res = eng.serve(fe, page_size=4, max_slots=1)
    assert res["shed"] == [b.rid]
    assert res["stats"].shed == [b.rid] and res["stats"].n_shed == 1
    assert res["outputs"][b.rid].size == 0
    assert b.rid not in res["stats"].queue_wait_s
    ref = eng.generate(prompt_a[None], 12)["tokens"][0]
    np.testing.assert_array_equal(res["outputs"][a.rid], ref)
    # a was admitted immediately: exempt from shedding despite long service
    assert a.rid in res["stats"].e2e_s


def test_serve_speculative_rides_open_loop():
    """speculative=True runs through the same serve() back-end
    (synchronously) with staggered arrivals, keeping the for-any-draft
    parity contract."""
    cfg, eng = _engine("internlm2-20b", max_len=32)
    fe, clk = _vclock_frontend()
    reqs = _requests(cfg.vocab, MIXED[:4], seed=17)
    rids = [fe.submit(r, at=0.004 * i).rid for i, r in enumerate(reqs)]
    res = eng.serve(fe, page_size=4, max_slots=4, speculative=True,
                    draft_k=3)
    assert not res["stats"].overlapped        # spec steps synchronously
    assert res["stats"].draft_proposed > 0
    for rid, (toks, n) in zip(rids, reqs):
        ref = eng.generate(toks[None], n)["tokens"][0]
        np.testing.assert_array_equal(res["outputs"][rid], ref,
                                      err_msg=f"rid {rid}")


# ------------------------------------------------------ jit-variant bounds
def test_trace_counts_independent_of_arrival_pattern():
    """Regression (extends the closed-loop trace-count gate): staggered
    open-loop arrivals compile exactly the variants the all-at-once run
    does -- 2 model_step shapes, <= 2 sampler shapes -- and the retired
    per-lane host sampling path never reappears."""
    cfg = ARCHS["internlm2-20b"].smoke
    model = LM(cfg)
    params = model.init(KEY)

    def counts(stagger):
        eng = ServeEngine(model, params, max_len=32)
        fe, clk = _vclock_frontend()
        reqs = _requests(cfg.vocab, MIXED, seed=23)
        for i, r in enumerate(reqs):
            fe.submit(r, at=(0.005 * i if stagger else 0.0))
        eng.serve(fe, page_size=4, max_slots=4)
        return dict(eng.trace_counts)

    open_loop, closed = counts(True), counts(False)
    assert open_loop["model_step"] == closed["model_step"]
    assert open_loop["model_step"] <= 2
    assert open_loop.get("sample_step", 0) <= 2
    assert open_loop.get("prefill", 0) == 0
    assert open_loop.get("decode_step_paged", 0) == 0
