"""HLO analyzer: loop-corrected FLOPs + collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo import analyze, _ring_factor

pytestmark = pytest.mark.slow


def test_scan_flops_multiplied_by_trip_count():
    TRIPS, M, K, N = 5, 8, 16, 12

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((TRIPS, K, K), jnp.float32)).compile()
    stats = analyze(comp.as_text(), default_group=1)
    want = TRIPS * 2 * M * K * K
    assert abs(stats.flops - want) / want < 0.01, (stats.flops, want)
    # jax's own cost_analysis under-reports by ~TRIPS
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):   # jax 0.4.x: one dict per device
        ca = ca[0]
    assert stats.flops > ca["flops"] * (TRIPS - 1)


def test_plain_matmul_flops():
    M, K, N = 32, 64, 16
    comp = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
    stats = analyze(comp.as_text(), default_group=1)
    assert abs(stats.flops - 2 * M * K * N) / (2 * M * K * N) < 0.01


def test_nested_scan_flops():
    def f(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return jnp.tanh(ci @ wi), None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, w)
        return y

    M, K, TRIPS = 8, 8, 4
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((TRIPS, K, K), jnp.float32)).compile()
    stats = analyze(comp.as_text(), default_group=1)
    want = TRIPS * 3 * 2 * M * K * K
    assert abs(stats.flops - want) / want < 0.01


def test_ring_factors():
    assert _ring_factor("all-reduce", 2) == 1.0
    assert _ring_factor("all-gather", 16) == 15 / 16
    assert _ring_factor("reduce-scatter", 4) == 3.0
    assert _ring_factor("all-reduce", 1) == 0.0


def test_bytes_written_positive_and_loop_scaled():
    def f(x):
        def body(c, _):
            return jnp.tanh(c) * 2.0, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    stats = analyze(comp.as_text(), default_group=1)
    assert stats.bytes_written >= 10 * 128 * 128 * 4 * 0.5
