"""Per-architecture smoke tests (reduced same-family configs, CPU) +
train/prefill/decode consistency for one arch per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import LM
from repro.models.cnn import CNN, CIF10_TINY

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B, S):
    batch = {}
    if cfg.frontend == "audio_stub":
        batch["embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.3
    else:
        batch["tokens"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    if cfg.frontend == "vision_stub":
        batch["img_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_img_tokens, cfg.d_model)) * 0.3
    batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch_id):
    """One forward + one train (grad) step: shapes right, all finite."""
    spec = ARCHS[arch_id]
    cfg = spec.smoke
    model = LM(cfg)
    params = model.init(KEY)
    batch = _batch(cfg, B=2, S=16)
    logits, aux = model.apply(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab]).all())
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


CONSISTENCY_ARCHS = ["jamba-1.5-large-398b", "gemma2-2b", "mamba2-780m",
                     "llama-3.2-vision-90b", "granite-moe-3b-a800m"]


@pytest.mark.parametrize("arch_id", CONSISTENCY_ARCHS)
def test_prefill_decode_matches_full_forward(arch_id):
    cfg = ARCHS[arch_id].smoke
    model = LM(cfg)
    params = model.init(KEY)
    B, S, Sp = 2, 12, 8
    batch = _batch(cfg, B, S)
    batch.pop("labels")
    full_logits, _ = model.apply(params, batch)

    pb = dict(batch)
    key_tok = "embeds" if cfg.frontend == "audio_stub" else "tokens"
    pb[key_tok] = batch[key_tok][:, :Sp]
    cache = model.init_cache(B, S, dtype=jnp.float32)
    lg, cache = model.prefill(params, pb, cache)
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, Sp - 1])))]
    for t in range(Sp, S):
        tok = batch[key_tok][:, t:t + 1]
        lg, cache = model.decode_step(params, tok, cache, jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, t]))))
    assert max(errs) < 1e-3, errs


def test_remat_matches_no_remat():
    cfg = ARCHS["internlm2-20b"].smoke
    model = LM(cfg)
    params = model.init(KEY)
    batch = _batch(cfg, 2, 8)
    l1 = model.loss(params, batch, remat=False)
    l2 = model.loss(params, batch, remat=True)
    assert abs(float(l1) - float(l2)) < 1e-5
    g1 = jax.grad(lambda p: model.loss(p, batch, remat=False))(params)
    g2 = jax.grad(lambda p: model.loss(p, batch, remat=True))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_sliding_window_masks_far_context():
    """Local attention must ignore tokens beyond the receptive field
    L * (window - 1); global layers must not."""
    from repro.models.api import BlockDef, LMConfig
    base = ARCHS["gemma2-2b"].smoke
    cfg = LMConfig(name="pure-local", d_model=base.d_model,
                   n_heads=base.n_heads, n_kv_heads=base.n_kv_heads,
                   d_ff=base.d_ff, vocab=base.vocab, n_layers=4,
                   head_dim=base.head_dim,
                   pattern=(BlockDef(kind="local_attn"),), window=8)
    model = LM(cfg)
    params = model.init(KEY)
    S = 64                                # receptive field = 4*(8-1) = 28
    t1 = jax.random.randint(KEY, (1, S), 0, cfg.vocab)
    t2 = t1.at[:, 0].set((t1[:, 0] + 1) % cfg.vocab)  # perturb far-past token
    l1, _ = model.apply(params, {"tokens": t1})
    l2, _ = model.apply(params, {"tokens": t2})
    # near position: inside receptive field of token 0 -> differs
    assert float(jnp.max(jnp.abs(l1[:, 5] - l2[:, 5]))) > 0
    # far position: beyond the receptive field -> identical
    np.testing.assert_allclose(np.asarray(l1[:, 40:]), np.asarray(l2[:, 40:]),
                               atol=1e-5)
    # a global-attention layer in the same geometry DOES see token 0
    gcfg = LMConfig(name="g", d_model=base.d_model, n_heads=base.n_heads,
                    n_kv_heads=base.n_kv_heads, d_ff=base.d_ff,
                    vocab=base.vocab, n_layers=4, head_dim=base.head_dim,
                    pattern=(BlockDef(kind="attn"),))
    gm = LM(gcfg)
    gp = gm.init(KEY)
    g1, _ = gm.apply(gp, {"tokens": t1})
    g2, _ = gm.apply(gp, {"tokens": t2})
    assert float(jnp.max(jnp.abs(g1[:, 40:] - g2[:, 40:]))) > 1e-6


def test_cnn_smoke():
    model = CNN(CIF10_TINY)
    params = model.init(KEY)
    x = jax.random.normal(KEY, (4, 16, 16, 3))
    logits = model.apply(params, x)
    assert logits.shape == (4, 10)
    assert bool(jnp.isfinite(logits).all())
    graph = model.graph()
    assert graph.total_groups == sum(l.c_out for l in graph.layers)


def test_graph_paths_resolve():
    """Every LayerInfo param_path must index into the real params pytree."""
    for arch_id in sorted(ARCHS):
        cfg = ARCHS[arch_id].smoke
        model = LM(cfg)
        params = jax.eval_shape(lambda m=model: m.init(KEY))
        graph = model.graph(seq_len=8, batch=2)
        for layer in graph.layers:
            node = params
            for k in layer.param_path:
                node = node[k]
            assert node.shape[layer.channel_axis] == layer.c_out or \
                node.shape[layer.channel_axis] % layer.n_groups == 0
