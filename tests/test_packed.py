"""Sub-byte packed weight store: pack/unpack, kernel parity, serving parity.

The acceptance contract for the packed path: for every mixed-QBN policy the
packed matmul is allclose (atol 1e-4) to the jnp reference, pack->unpack is
the identity, and the packed store costs <= 60% of the int8 store's bytes on
a 4-bit-average policy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, pack, ref
from repro.quant import (fake_quant_per_channel, quant_pack_int8,
                         quant_pack_sub8)

RNG = np.random.default_rng(11)

# mixed per-group QBNs the searches land on, incl. prune (0) and full int8
MIXED_QBNS = [0, 2, 3, 4, 8]


def _mixed_bits(n):
    reps = int(np.ceil(n / len(MIXED_QBNS)))
    return np.asarray((MIXED_QBNS * reps)[:n], np.float32)


# ------------------------------------------------------------ pack / unpack
@settings(max_examples=15, deadline=None)
@given(store_bits=st.sampled_from([2, 4]), k=st.integers(1, 40),
       n=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(store_bits, k, n, seed):
    """pack -> unpack is the identity for any in-range values, any K parity."""
    rng = np.random.default_rng(seed)
    lo, hi = -(2 ** (store_bits - 1)), 2 ** (store_bits - 1) - 1
    q = rng.integers(lo, hi + 1, size=(k, n)).astype(np.int32)
    p = pack.pack_sub8(jnp.asarray(q), store_bits, axis=0)
    f = pack.SUB8_FACTORS[store_bits]
    assert p.shape == (-(-k // f), n) and p.dtype == jnp.int8
    u = pack.unpack_sub8(p, store_bits, k=k, axis=0)
    np.testing.assert_array_equal(np.asarray(u), q)


def test_pack_axis_generality():
    """Packing along a middle axis (stacked weights) round-trips too."""
    q = RNG.integers(-8, 8, size=(3, 21, 5)).astype(np.int32)
    p = pack.pack_sub8(jnp.asarray(q), 4, axis=-2)
    assert p.shape == (3, 11, 5)
    u = pack.unpack_sub8(p, 4, k=21, axis=-2)
    np.testing.assert_array_equal(np.asarray(u), q)


# ------------------------------------------------------------ Pallas kernel
@pytest.mark.parametrize("store_bits", [2, 4])
@pytest.mark.parametrize("shape", [(128, 128, 128), (64, 130, 70),
                                   (1, 96, 257), (100, 200, 48)])
def test_packed_matmul_allclose(store_bits, shape):
    """Packed Pallas kernel == jnp reference on aligned and ragged shapes."""
    M, K, N = shape
    lv = 2 ** (store_bits - 1) - 1
    q = RNG.integers(-lv, lv + 1, size=(K, N)).astype(np.int32)
    pw = pack.pack_sub8(jnp.asarray(q), store_bits, axis=0)
    x = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
    s = jnp.asarray(RNG.uniform(0.01, 0.1, size=(N,)), jnp.float32)
    y = ops.packed_matmul(x, pw, s, store_bits=store_bits)
    yr = ref.quant_matmul_ref(x, jnp.asarray(q, jnp.int8), s)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)


def test_packed_matmul_block_sweep():
    """Block shapes stay correct as long as bk is a multiple of 8/bits."""
    K, N = 256, 192
    q = RNG.integers(-1, 2, size=(K, N)).astype(np.int32)
    pw = pack.pack_sub8(jnp.asarray(q), 2, axis=0)
    x = jnp.asarray(RNG.normal(size=(64, K)), jnp.float32)
    s = jnp.asarray(RNG.uniform(0.01, 0.1, size=(N,)), jnp.float32)
    yr = ref.quant_matmul_ref(x, jnp.asarray(q, jnp.int8), s)
    for bm, bn, bk in [(64, 64, 64), (128, 128, 128), (64, 128, 256)]:
        y = ops.packed_matmul(x, pw, s, store_bits=2, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-4, atol=1e-3)


# ------------------------------------------------- bucketed layout + policy
def test_sub8_dequant_matches_fake_quant():
    """For QBN <= 8 the packed store round-trips to fake-quant numerics."""
    n = 40
    bits = _mixed_bits(n)
    w = jnp.asarray(RNG.normal(size=(70, n)), jnp.float32)
    pw = quant_pack_sub8(w, bits)
    dq = pw.dequant()
    fq = fake_quant_per_channel(w, jnp.asarray(bits), axis=-1)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(fq), atol=1e-6)
    # pruned channels really are zero, and stored as zero bytes
    nbytes = pw.bucket_nbytes()
    assert nbytes.get("pruned", 0) == 0
    assert bool(jnp.all(dq[:, bits == 0] == 0))


def test_all_pruned_stacked_keeps_lead_dims():
    """An all-pruned stacked (R, K, N) weight still dequantizes to
    (R, K, N) zeros -- the pruned bucket's zero-width sentinel carries the
    stack dims even when no bucket stores data."""
    w = jnp.asarray(RNG.normal(size=(3, 8, 4)), jnp.float32)
    pw = quant_pack_sub8(w, 0.0)
    assert pw.hbm_bytes() == 0
    dq = pw.dequant()
    assert dq.shape == (3, 8, 4)
    assert bool(jnp.all(dq == 0))


@pytest.mark.parametrize("shape", [(64, 96, 40), (33, 130, 37), (1, 64, 257)])
def test_mixed_qbn_matmul_parity(shape):
    """Bucketed dispatch == x @ fake-quant reference across mixed QBNs
    {0, 2, 3, 4, 8} and non-128-aligned M/K/N edges (atol 1e-4)."""
    M, K, N = shape
    bits = _mixed_bits(N)
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
    pw = quant_pack_sub8(w, bits)
    y = ops.packed_mixed_matmul(x, pw)
    wq = fake_quant_per_channel(w, jnp.asarray(bits), axis=-1)
    yr = x @ wq
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)


def test_fake_quant_padding_zero_scale_guard():
    """Non-aligned N exercises ops.py padding: padded channels carry scale 0
    pre-guard and must not poison real outputs with NaN/Inf."""
    M, N = 50, 70                       # N % 128 != 0 -> padding engaged
    x = jnp.asarray(RNG.normal(size=(M, N)), jnp.float32)
    bits = jnp.asarray(_mixed_bits(N), jnp.float32)
    lv = jnp.maximum(2.0 ** (bits - 1) - 1, 1.0)
    amax = jnp.max(jnp.abs(x), axis=0)
    sc = jnp.where(amax > 0, amax / lv, 1.0)
    y = ops.fake_quant_channels(x, sc, lv, bits)
    yr = ref.fake_quant_ref(x, sc, lv, bits)
    assert bool(jnp.all(jnp.isfinite(y)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)


def test_packed_store_bytes_leq_60pct_of_int8():
    """Acceptance: on a 4-bit-average policy the packed store costs <= 60%
    of the int8 store's weight-side HBM bytes."""
    K, N = 512, 320
    mix = [2, 3, 4, 4, 4, 4, 6, 8, 2, 3]          # avg 4.0
    bits = np.asarray((mix * (N // len(mix)))[:N], np.float32)
    assert abs(bits.mean() - 4.0) < 0.01
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    qi, si, _ = quant_pack_int8(w, bits, axis=1)
    int8_bytes = qi.size * qi.dtype.itemsize + si.size * si.dtype.itemsize
    packed_bytes = quant_pack_sub8(w, bits).hbm_bytes()
    assert packed_bytes <= 0.60 * int8_bytes, (packed_bytes, int8_bytes)


# ------------------------------------------------------------ serving path
def test_engine_packed_store_matches_fake_store():
    """Greedy decode through the packed store == fake-quant store (weights
    quantize on the same per-channel grid, so serving must be bit-identical
    for QBN <= 8 policies)."""
    from repro.configs import ARCHS
    from repro.models import LM
    from repro.quant.policy import QuantPolicy
    from repro.serve import ServeEngine

    key = jax.random.PRNGKey(0)
    cfg = ARCHS["gemma2-2b"].smoke
    model = LM(cfg)
    params = model.init(key)
    graph = model.graph(seq_len=4, batch=2)
    policy = QuantPolicy.uniform(graph, 4.0)
    rng = np.random.default_rng(0)
    for l in graph.layers:
        policy.weight_bits[l.name] = rng.choice(
            [2, 3, 4, 4, 8], size=l.n_groups).astype(np.float32)
    tokens = np.asarray(jax.random.randint(key, (2, 5), 0, cfg.vocab))
    eng_fake = ServeEngine(model, params, policy=policy, graph=graph,
                           max_len=16)
    eng_pack = ServeEngine(model, params, policy=policy, graph=graph,
                           max_len=16, weight_store="packed")
    out_f = eng_fake.generate(tokens, n_new=3)
    out_p = eng_pack.generate(tokens, n_new=3)
    np.testing.assert_array_equal(out_f["tokens"], out_p["tokens"])
    hbm_f = eng_fake.weight_hbm_bytes()
    hbm_p = eng_pack.weight_hbm_bytes()
    assert hbm_p["packed"] > 0
    assert hbm_p["total"] < 0.5 * hbm_f["total"]    # ~4-bit avg vs f32
