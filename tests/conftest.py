"""Shared fixtures.  NOTE: no XLA_FLAGS here -- smoke tests must see the
single real CPU device; only launch/dryrun.py requests 512 placeholders."""
import os
import sys

# Make `from hypothesis import ...` work before test modules are collected:
# prefer the real library, fall back to the fixed-seed shim.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_compat
    _hypothesis_compat.install()

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
