"""Pallas attention kernels vs the jnp oracle.

Property tests (hypothesis) drive both kernels across GQA ratios, sliding
windows, softcaps, ragged page counts and mixed in-flight lengths, always
comparing against ``models.layers.attention_ref`` / ``paged_attention_ref``
-- the pure-jnp flash schedule that predates the kernels and stays their
bit-accuracy oracle.  Tolerances are the documented f32 online-softmax
rescale rounding (~1e-7 per tile); single-tile cases reproduce the oracle
bit for bit (asserted explicitly).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.attention import (flash_attention, paged_decode_attention,
                                     paged_prefill_attention)
from repro.models import layers
from repro.models.layers import (attention, attention_ref, paged_attention,
                                 paged_attention_ref)
from repro.models.transformer import POS_SENTINEL, _kv_quant

# documented f32-accumulation tolerance: online-softmax rescale rounding
TOL = dict(rtol=2e-4, atol=2e-5)


def _qkv(rng, B, Sq, Skv, Hkv, G, D):
    q = jnp.asarray(rng.normal(size=(B, Sq, Hkv * G, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), jnp.float32)
    q_pos = jnp.broadcast_to(
        jnp.arange(Skv - Sq, Skv, dtype=jnp.int32)[None], (B, Sq))
    kv_pos = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32)[None],
                              (B, Skv))
    return q, k, v, q_pos, kv_pos


# ------------------------------------------------------------ flash prefill
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), Sq=st.integers(1, 12),
       Skv=st.integers(1, 40), hkv=st.sampled_from([1, 2]),
       g=st.sampled_from([1, 2, 4]), window=st.sampled_from([None, 5, 16]),
       cap=st.sampled_from([None, 30.0]))
def test_flash_kernel_matches_oracle(seed, Sq, Skv, hkv, g, window, cap):
    """Multi-tile flash kernel == jnp oracle across GQA ratios, windows,
    softcaps (small bq/bk force the online-softmax accumulation path)."""
    Sq = min(Sq, Skv)
    rng = np.random.default_rng(seed)
    q, k, v, q_pos, kv_pos = _qkv(rng, 2, Sq, Skv, hkv, g, 8)
    ref = attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos, window=window,
                        attn_cap=cap, chunk=10**9)
    got = flash_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, window=window,
                          attn_cap=cap, bq=8, bk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


def test_flash_kernel_single_tile_bitwise_and_noncausal():
    """One KV tile degenerates to the oracle's single-shot softmax -- bit
    equality, not just allclose; non-causal (cross-attention) included."""
    rng = np.random.default_rng(0)
    q, k, v, q_pos, kv_pos = _qkv(rng, 2, 12, 40, 2, 3, 16)
    for causal in (True, False):
        ref = attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                            causal=causal, chunk=10**9)
        got = flash_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                              causal=causal, bq=128, bk=128)
        assert bool(jnp.all(got == ref)), f"causal={causal}"


def test_flash_kernel_ring_buffer_positions():
    """Ring (rolled) kv_pos order -- the dense local_attn decode layout --
    masks by position value, not storage index."""
    rng = np.random.default_rng(1)
    W, B, Hkv, G, D = 8, 2, 2, 2, 8
    k = jnp.asarray(rng.normal(size=(B, W, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, W, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, 1, Hkv * G, D)), jnp.float32)
    pos = jnp.asarray([[(i - 3) % W + 5 for i in range(W)]] * B, jnp.int32)
    q_pos = jnp.full((B, 1), 12, jnp.int32)
    ref = attention_ref(q, k, v, q_pos=q_pos, kv_pos=pos, window=W)
    got = flash_attention(q, k, v, q_pos=q_pos, kv_pos=pos, window=W, bk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


# --------------------------------------------------------------- dispatcher
def test_attention_dispatcher_impls_agree_and_validate():
    rng = np.random.default_rng(2)
    q, k, v, q_pos, kv_pos = _qkv(rng, 2, 6, 24, 2, 2, 8)
    ref = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, impl="ref")
    default = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(default))
    pal = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, impl="pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), **TOL)
    with pytest.raises(ValueError, match="impl"):
        attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, impl="cuda")
    assert layers._check_impl(None) == "ref"


# ----------------------------------------------------------- paged decode
def _paged_pool(rng, lens, ps, Hkv, D, kv_bits=None, extra_blocks=1):
    """Build a pool + block tables for sequences of the given lengths.

    Returns (q, pools dict, block_tables, q_pos): sequence i has written
    positions 0..lens[i]-1 (q_pos = lens[i]-1 attends all of them);
    lens[i] == 0 marks an idle lane (all-trash table, sentinel q_pos).
    """
    B = len(lens)
    nb = max(-(-max(lens) // ps), 1) + extra_blocks   # ragged not-grown tail
    P = 1 + sum(-(-s // ps) for s in lens if s)
    kf = rng.normal(size=(P, ps, Hkv, D)).astype(np.float32)
    vf = rng.normal(size=(P, ps, Hkv, D)).astype(np.float32)
    pos = np.full((P, ps), POS_SENTINEL, np.int32)
    bt = np.zeros((B, nb), np.int32)
    nxt = 1
    for i, s in enumerate(lens):
        npages = -(-s // ps)
        bt[i, :npages] = range(nxt, nxt + npages)
        for p in range(s):
            pos[bt[i, p // ps], p % ps] = p
        nxt += npages
    q = jnp.asarray(rng.normal(size=(B, 1, Hkv * 2, D)), jnp.float32)
    q_pos = jnp.asarray([[s - 1 if s else POS_SENTINEL] for s in lens],
                        jnp.int32)
    pools = {"k": jnp.asarray(kf), "v": jnp.asarray(vf),
             "pos": jnp.asarray(pos), "k_s": None, "v_s": None}
    if kv_bits == 8:
        kq, ks = _kv_quant(pools["k"])
        vq, vs = _kv_quant(pools["v"])
        pools = {"k": kq, "v": vq, "pos": pools["pos"], "k_s": ks, "v_s": vs}
    return q, pools, jnp.asarray(bt), q_pos


def _run_paged(q, pools, bt, q_pos, impl, **kw):
    return paged_attention(q, pools["k"], pools["v"], pools["pos"], bt,
                           q_pos=q_pos, k_scale_pages=pools["k_s"],
                           v_scale_pages=pools["v_s"], impl=impl, **kw)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), ps=st.sampled_from([4, 8]),
       window=st.sampled_from([None, 6]), cap=st.sampled_from([None, 30.0]),
       lens=st.lists(st.integers(0, 25), min_size=1, max_size=5))
def test_paged_kernel_matches_oracle(seed, ps, window, cap, lens):
    """Block-table walk == dense gather + oracle, across ragged page
    counts, mixed in-flight lengths, idle lanes, windows and softcaps."""
    if not any(lens):
        lens = lens + [3]
    rng = np.random.default_rng(seed)
    q, pools, bt, q_pos = _paged_pool(rng, lens, ps, Hkv=2, D=8)
    ref = _run_paged(q, pools, bt, q_pos, "ref", window=window, attn_cap=cap)
    got = _run_paged(q, pools, bt, q_pos, "pallas", window=window,
                     attn_cap=cap)
    active = [i for i, s in enumerate(lens) if s]
    np.testing.assert_allclose(np.asarray(got)[active],
                               np.asarray(ref)[active], **TOL)
    # idle lanes: every slot masks -> exact zeros (the oracle leaves them
    # attending trash; the scheduler ignores both)
    idle = [i for i, s in enumerate(lens) if not s]
    assert np.all(np.asarray(got)[idle] == 0.0)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), window=st.sampled_from([None, 6]))
def test_paged_kernel_int8_pages_match_oracle(seed, window):
    """int8 pools: in-VMEM dequant == gather-then-dequant oracle."""
    rng = np.random.default_rng(seed)
    q, pools, bt, q_pos = _paged_pool(rng, [10, 3, 17], 4, Hkv=2, D=8,
                                      kv_bits=8)
    ref = _run_paged(q, pools, bt, q_pos, "ref", window=window)
    got = _run_paged(q, pools, bt, q_pos, "pallas", window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


def test_paged_kernel_requires_scales_iff_int8():
    rng = np.random.default_rng(3)
    q, pools, bt, q_pos = _paged_pool(rng, [5], 4, Hkv=2, D=8, kv_bits=8)
    with pytest.raises(AssertionError, match="scale"):
        paged_decode_attention(q, pools["k"], pools["v"], pools["pos"], bt,
                               q_pos=q_pos)


def test_paged_kernel_window_skips_leading_blocks():
    """With a sliding window, the walk re-bases at the first in-window
    block -- the result still matches the oracle even when most of the
    sequence's pages are out of window."""
    rng = np.random.default_rng(4)
    q, pools, bt, q_pos = _paged_pool(rng, [24], 4, Hkv=2, D=8)
    ref = _run_paged(q, pools, bt, q_pos, "ref", window=5)
    got = _run_paged(q, pools, bt, q_pos, "pallas", window=5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


# ------------------------------------------------- paged prefill (q tiles)
def _paged_chunk_pool(rng, lens, k, ps, Hkv, G, D, kv_bits=None):
    """Pool + q tiles for the chunked-prefill layout.

    Sequence i has ``lens[i]`` written positions; its q tile is the *last*
    ``c = min(k, lens[i])`` of them (the chunk just scattered into the pool,
    mirroring model_step's write-then-attend order), left-aligned with
    sentinel padding -- so chunk offsets, ragged page counts and idle lanes
    (lens[i] == 0) all appear.  Returns (q (B,k,Hq,D), pools, bt, q_pos).
    """
    B = len(lens)
    nb = max(-(-max(lens) // ps), 1) + 1
    P = 1 + sum(-(-s // ps) for s in lens if s)
    kf = rng.normal(size=(P, ps, Hkv, D)).astype(np.float32)
    vf = rng.normal(size=(P, ps, Hkv, D)).astype(np.float32)
    pos = np.full((P, ps), POS_SENTINEL, np.int32)
    bt = np.zeros((B, nb), np.int32)
    q_pos = np.full((B, k), POS_SENTINEL, np.int32)
    nxt = 1
    for i, s in enumerate(lens):
        npages = -(-s // ps)
        bt[i, :npages] = range(nxt, nxt + npages)
        for p in range(s):
            pos[bt[i, p // ps], p % ps] = p
        c = min(k, s)
        q_pos[i, :c] = range(s - c, s)
        nxt += npages
    q = jnp.asarray(rng.normal(size=(B, k, Hkv * G, D)), jnp.float32)
    pools = {"k": jnp.asarray(kf), "v": jnp.asarray(vf),
             "pos": jnp.asarray(pos), "k_s": None, "v_s": None}
    if kv_bits == 8:
        kq, ks = _kv_quant(pools["k"])
        vq, vs = _kv_quant(pools["v"])
        pools = {"k": kq, "v": vq, "pos": pools["pos"], "k_s": ks, "v_s": vs}
    return q, pools, jnp.asarray(bt), jnp.asarray(q_pos)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), ps=st.sampled_from([4, 8]),
       k=st.sampled_from([2, 3, 5, 8]), hkv=st.sampled_from([1, 2]),
       g=st.sampled_from([1, 2, 4]), window=st.sampled_from([None, 6]),
       cap=st.sampled_from([None, 30.0]),
       lens=st.lists(st.integers(0, 25), min_size=1, max_size=4))
def test_paged_prefill_kernel_matches_oracle(seed, ps, k, hkv, g, window,
                                             cap, lens):
    """Acceptance: the q-tile block-table walk == dense gather + oracle
    across chunk sizes x windows x GQA ratios x ragged page counts x chunk
    offsets (tiles mid-sequence), softcaps and idle lanes."""
    if not any(lens):
        lens = lens + [3]
    rng = np.random.default_rng(seed)
    q, pools, bt, q_pos = _paged_chunk_pool(rng, lens, k, ps, hkv, g, 8)
    ref = np.asarray(_run_paged(q, pools, bt, q_pos, "ref", window=window,
                                attn_cap=cap))
    got = np.asarray(_run_paged(q, pools, bt, q_pos, "pallas", window=window,
                                attn_cap=cap))
    # compare the real (left-aligned) columns only: sentinel-padded columns
    # are never read by the scheduler, and the jnp oracle's mask has no
    # sentinel-q test (a sentinel q row attends everything under global
    # attention) while the kernel masks them -- a deliberate difference on
    # dead lanes
    for i, s in enumerate(lens):
        c = min(k, s)
        np.testing.assert_allclose(got[i, :c], ref[i, :c], err_msg=f"row {i}",
                                   **TOL)
    # idle rows (all-trash tables, all slots sentinel) produce exact zeros
    idle = [i for i, s in enumerate(lens) if not s]
    assert np.all(got[idle] == 0.0)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), window=st.sampled_from([None, 6]),
       k=st.sampled_from([2, 4]))
def test_paged_prefill_kernel_int8_pages_match_oracle(seed, window, k):
    """int8 pools under q tiles: in-VMEM dequant == gather-then-dequant."""
    rng = np.random.default_rng(seed)
    lens = [10, 3, 17]
    q, pools, bt, q_pos = _paged_chunk_pool(rng, lens, k, 4, 2, 2, 8,
                                            kv_bits=8)
    ref = np.asarray(_run_paged(q, pools, bt, q_pos, "ref", window=window))
    got = np.asarray(_run_paged(q, pools, bt, q_pos, "pallas", window=window))
    for i, s in enumerate(lens):           # real columns (see above)
        np.testing.assert_allclose(got[i, :min(k, s)], ref[i, :min(k, s)],
                                   err_msg=f"row {i}", **TOL)


def test_paged_prefill_single_page_single_tile_bitwise():
    """One page and one q tile degenerate to the oracle's single-shot
    softmax: bit equality, like the flash kernel's single-tile case."""
    rng = np.random.default_rng(7)
    q, pools, bt, q_pos = _paged_chunk_pool(rng, [4], 3, 8, 2, 2, 8)
    ref = _run_paged(q, pools, bt, q_pos, "ref")
    got = _run_paged(q, pools, bt, q_pos, "pallas")
    assert bool(jnp.all(got == ref))


def test_paged_decode_is_the_k1_tile():
    """The decode entry point is exactly the k == 1 q tile of the prefill
    kernel (same kernel, same numerics)."""
    rng = np.random.default_rng(8)
    q, pools, bt, q_pos = _paged_pool(rng, [9, 4], 4, Hkv=2, D=8)
    dec = paged_decode_attention(q, pools["k"], pools["v"], pools["pos"], bt,
                                 q_pos=q_pos)
    pre = paged_prefill_attention(q, pools["k"], pools["v"], pools["pos"],
                                  bt, q_pos=q_pos.reshape(-1, 1))
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(pre))
