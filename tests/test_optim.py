"""AdamW (fp32 + 8-bit states) tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamW, cosine_warmup


def _quad_problem():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(16, 16)),
                         jnp.float32)
    params = {"w": jnp.zeros((16, 16)), "nested": ({"b": jnp.zeros(16)},)}

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2) + \
            jnp.mean((p["nested"][0]["b"] - 1.0) ** 2)

    return params, loss


@pytest.mark.parametrize("bits,target", [(32, 0.05), (8, 0.05)])
def test_adamw_converges(bits, target):
    # 8-bit mode stores v in the sqrt domain, recovering fp32-grade
    # convergence (linear-absmax v diverges; see optim/adam.py).
    params, loss = _quad_problem()
    opt = AdamW(lr=5e-2, state_bits=bits)
    state = opt.init(params)
    step = jax.jit(lambda p, s: opt.update(p, jax.grad(loss)(p), s))
    l0 = float(loss(params))
    for _ in range(200):
        params, state, _ = step(params, state)
    assert float(loss(params)) < l0 * target


def test_8bit_state_layout():
    params, _ = _quad_problem()
    opt = AdamW(state_bits=8)
    state = opt.init(params)
    assert state["m"]["w"]["q"].dtype == jnp.int8
    assert state["m"]["w"]["s"].shape == (16, 1)
    assert state["m"]["nested"][0]["b"]["s"].shape == (1,)


def test_8bit_tracks_fp32_closely():
    params, loss = _quad_problem()
    o32, o8 = AdamW(lr=2e-2, state_bits=32), AdamW(lr=2e-2, state_bits=8)
    p32 = p8 = params
    s32, s8 = o32.init(params), o8.init(params)
    for _ in range(50):
        g32 = jax.grad(loss)(p32)
        p32, s32, _ = o32.update(p32, g32, s32)
        g8 = jax.grad(loss)(p8)
        p8, s8, _ = o8.update(p8, g8, s8)
    l0 = float(loss(_quad_problem()[0]))
    l32, l8 = float(loss(p32)), float(loss(p8))
    assert l32 < l0 * 0.5
    assert l8 < l0 * 0.5                # sqrt-domain v tracks fp32 closely


def test_grad_clip():
    params = {"w": jnp.zeros(4)}
    opt = AdamW(lr=1.0, grad_clip=1e-3)
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e9)}
    newp, _, m = opt.update(params, huge, state)
    assert float(m["grad_norm"]) > 1e8
    assert float(jnp.max(jnp.abs(newp["w"]))) < 10.0


def test_cosine_warmup_shape():
    lrs = [float(cosine_warmup(s, base_lr=1.0, warmup=10, total=100))
           for s in range(101)]
    assert lrs[0] < lrs[5] < lrs[10]
    assert abs(lrs[10] - 1.0) < 1e-5
    assert lrs[-1] < 0.2
