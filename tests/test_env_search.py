"""QuantEnv + search integration (tiny budgets; the full 400-episode runs
live in benchmarks/ and EXPERIMENTS.md)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (FlatAgent, HierarchicalAgent, LayerBounder, QuantEnv,
                        RewardCfg, make_cnn_evaluator, make_lm_evaluator,
                        run_search)
from repro.configs import ARCHS
from repro.data import SyntheticImages, TokenStream
from repro.models import LM
from repro.models.cnn import CNN, CNNConfig
from repro.quant.policy import QuantMode, QuantPolicy

KEY = jax.random.PRNGKey(0)
CNN_CFG = CNNConfig(name="t", img_size=8, channels=(8, 16), pool_after=(0,))


def _cnn_env(reward=None, mode=QuantMode.QUANT, bounder=None):
    model = CNN(CNN_CFG)
    params = model.init(KEY)
    val = SyntheticImages(img_size=8).batch(999, 64)
    graph = model.graph()
    ev = make_cnn_evaluator(model, params, graph, val, mode=mode)
    b = LayerBounder(graph, 5.0, 5.0) if bounder else None
    return QuantEnv(graph, params, ev,
                    reward or RewardCfg.accuracy_guaranteed(), mode=mode,
                    bounder=b), model, params, graph, ev


def test_evaluator_full_bits_matches_unquantized():
    env, model, params, graph, ev = _cnn_env()
    val = SyntheticImages(img_size=8).batch(999, 64)
    acc_raw = float(model.accuracy(
        params, {k: jnp.asarray(v) for k, v in val.items()})) * 100
    acc32 = ev(QuantPolicy.uniform(graph, 32.0))
    assert abs(acc_raw - acc32) < 1e-3


def test_hierarchical_episode_produces_valid_policy():
    env, *_ = _cnn_env()
    agent = HierarchicalAgent(env, seed=0)
    log, policy = agent.run_episode(noise=0.5)
    for layer in env.graph.layers:
        wb = policy.weight_bits[layer.name]
        assert wb.shape == (layer.n_groups,)
        assert ((wb >= 0) & (wb <= 32)).all()
        assert 0 <= policy.act_bits[layer.name] <= 32
    assert np.isfinite(log.reward)


def test_search_tracks_best():
    env, *_ = _cnn_env()
    agent = HierarchicalAgent(env, seed=0, updates_per_episode=2)
    res = run_search(agent, n_explore=2, n_exploit=2)
    assert len(res.history) == 4
    assert res.best_log.reward == max(h.reward for h in res.history)
    assert res.best_policy is not None


def test_flat_agents_run():
    for gran in ("layer", "channel"):
        env, *_ = _cnn_env()
        agent = FlatAgent(env, granularity=gran, updates_per_episode=2)
        res = run_search(agent, n_explore=1, n_exploit=1)
        assert len(res.history) == 2


def test_binarize_mode_search():
    env, *_ = _cnn_env(mode=QuantMode.BINARIZE)
    agent = HierarchicalAgent(env, seed=0, updates_per_episode=2)
    log, policy = agent.run_episode(noise=0.5)
    assert policy.mode == QuantMode.BINARIZE
    assert np.isfinite(log.acc)


def test_resource_constrained_respects_budget_direction():
    env, *_ = _cnn_env(reward=RewardCfg.resource_constrained(), bounder=True)
    agent = HierarchicalAgent(env, seed=0, updates_per_episode=2)
    log, policy = agent.run_episode(noise=0.3)
    # with the bounder active the average goal cannot exceed ~2x target
    assert log.avg_wbits <= 16.0


def test_lm_env_search_smoke():
    cfg = ARCHS["phi4-mini-3.8b"].smoke
    model = LM(cfg)
    params = model.init(KEY)
    val = TokenStream(vocab=cfg.vocab).batch(0, 4, 16)
    graph = model.graph(seq_len=16, batch=4, max_groups=8)
    ev = make_lm_evaluator(model, params, graph, val)
    env = QuantEnv(graph, params, ev, RewardCfg.accuracy_guaranteed())
    agent = HierarchicalAgent(env, seed=0, updates_per_episode=2)
    log, policy = agent.run_episode(noise=0.5)
    assert np.isfinite(log.reward)
    assert set(policy.weight_bits) == {l.name for l in graph.layers}


def test_hiro_relabel_modes():
    env, *_ = _cnn_env()
    for mode in ("min", "ml"):
        agent = HierarchicalAgent(env, seed=0, relabel=mode,
                                  updates_per_episode=1)
        log, _ = agent.run_episode(noise=0.5)
        assert np.isfinite(log.reward)
