"""Checkpoint manager tests: atomic roundtrip, bf16/int8, keep-k, elastic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager


def _tree(key):
    return {
        "a": jax.random.normal(key, (4, 8)),
        "blocks": ({"w": jax.random.normal(key, (2, 3)).astype(jnp.bfloat16)},
                   {"w": jnp.arange(6, dtype=jnp.int8).reshape(2, 3)}),
        "t": jnp.int32(7),
    }


def test_roundtrip_all_dtypes(tmp_path, key):
    cm = CheckpointManager(tmp_path, keep=2)
    tree = _tree(key)
    cm.save(5, tree, extra={"note": "hi"})
    like = jax.eval_shape(lambda: tree)
    step, restored, extra = cm.restore(like)
    assert step == 5 and extra["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_k_gc(tmp_path, key):
    cm = CheckpointManager(tmp_path, keep=2)
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        cm.save(s, tree)
    assert cm.all_steps() == [3, 4]
    assert cm.latest_step() == 4


def test_restore_shape_mismatch_raises(tmp_path, key):
    cm = CheckpointManager(tmp_path)
    cm.save(1, {"x": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        cm.restore({"x": jax.ShapeDtypeStruct((4,), jnp.float32)})


def test_restore_missing_leaf_raises(tmp_path, key):
    cm = CheckpointManager(tmp_path)
    cm.save(1, {"x": jnp.zeros((3,))})
    with pytest.raises(KeyError):
        cm.restore({"y": jax.ShapeDtypeStruct((3,), jnp.float32)})


def test_elastic_restore_with_shardings(tmp_path, key):
    """Restore onto explicit (degenerate 1x1 mesh) shardings -- the elastic
    re-mesh path: logical layout is mesh-independent."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    cm = CheckpointManager(tmp_path)
    tree = {"w": jax.random.normal(key, (8, 4))}
    cm.save(3, tree)
    mesh = make_host_mesh()
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    step, restored, _ = cm.restore(jax.eval_shape(lambda: tree), shardings=sh)
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.asarray(restored["w"]))
    assert restored["w"].sharding == sh["w"]
