"""Fixed-seed fallback for the `hypothesis` subset the test suite uses.

When `hypothesis` is installed (see requirements-dev.txt) the real library is
used and this module is inert.  When it is not -- minimal CI images, the
bare jax_pallas container -- conftest.py calls :func:`install`, which
registers this module under ``sys.modules["hypothesis"]`` *before* test
collection, so ``from hypothesis import given, settings, strategies as st``
keeps working everywhere.

The shim implements deterministic random sampling (seeded per test function)
rather than true property-based search: each ``@given`` test runs
``max_examples`` times with kwargs drawn from the declared strategies.  No
shrinking, no database, no health checks -- but the same assertions run over
the same kind of input distribution, and failures print the falsifying
example so they can be pinned as regression tests.

Supported API (the subset the suite imports):
  given(**kwargs), settings(max_examples=, deadline=),
  strategies.integers / floats / sampled_from / lists / tuples / booleans /
  just.
"""
from __future__ import annotations

import sys
import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    """A draw rule: rng -> value.  Mirrors hypothesis' SearchStrategy shape
    only as far as the suite needs (composition via lists/tuples)."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(
        lambda rng: int(rng.integers(min_value, int(max_value) + 1)))


def floats(min_value: float, max_value: float, **_ignored) -> _Strategy:
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def just(value) -> _Strategy:
    return _Strategy(lambda rng: value)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10, **_ignored) -> _Strategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(draw)


def tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


def given(*args, **kwargs):
    if args:
        raise NotImplementedError(
            "compat shim supports keyword strategies only")

    def decorate(fn):
        def runner():
            # settings() may decorate outside given() (sets the attribute on
            # runner) or inside it (sets it on the original fn)
            n = getattr(runner, "_max_examples",
                        getattr(fn, "_max_examples", DEFAULT_MAX_EXAMPLES))
            # per-function fixed seed: deterministic across runs, varied
            # across tests
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in kwargs.items()}
                try:
                    fn(**drawn)
                except BaseException:
                    print(f"\n[hypothesis-compat] falsifying example for "
                          f"{fn.__name__}: {drawn}", file=sys.stderr)
                    raise
        # copy identity by hand; functools.wraps would set __wrapped__ and
        # pytest would then see the original (strategy) parameters as
        # fixture requests
        runner.__name__ = fn.__name__
        runner.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner._hypothesis_compat = True
        return runner

    return decorate


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def decorate(fn):
        fn._max_examples = max_examples
        return fn
    return decorate


def install():
    """Register this module as `hypothesis` (+`.strategies`) in sys.modules."""
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for f in (integers, floats, booleans, just, sampled_from, lists, tuples):
        setattr(st, f.__name__, f)
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.__compat_shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
