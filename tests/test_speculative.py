"""Speculative multi-token decode: accept/rollback properties + parity.

The acceptance contract (docs/speculative.md): ``run(speculative=True)``
emits token streams **bit-identical** to a non-speculative ``run()`` /
independent ``generate()`` calls, for *any* draft -- acceptance changes
speed, never output -- and a verify step's over-speculated KV pages roll
back the same step, leaving pool occupancy exactly where plain decode
would have it (no leaked pages).

Three layers of coverage:

* unit: ``BlockTables.truncate_to`` (the rollback primitive);
* scheduler-level hypothesis: random draft agreement x draft_k x page
  sizes drive ``plan_step(draft_k) -> record -> rollback_speculation``
  with no model in the loop, pinning the exact-occupancy invariant;
* engine-level: stream parity across drafts (shallow prefix, full-depth
  self-agreeing, low-bit, and -- in the @slow hypothesis sweep -- a
  noise-corrupted draft with *random* agreement patterns), sliding
  windows, int8 KV, packed weights, sampled requests.
"""
import dataclasses as dc

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS
from repro.models import LM
from repro.serve import (PageAllocator, Request, Scheduler, ServeEngine,
                         pages_needed)
from repro.serve import paged_kv

KEY = jax.random.PRNGKey(0)
MIXED = [(3, 5), (7, 4), (5, 6), (9, 3), (2, 5), (6, 4)]


def _requests(vocab, shapes, seed=3):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, vocab, size=s).astype(np.int32), n)
            for s, n in shapes]


def _engine(arch_id, **kw):
    cfg = ARCHS[arch_id].smoke
    model = LM(cfg)
    params = model.init(KEY)
    return cfg, ServeEngine(model, params, **kw)


def _assert_spec_matches_generate(eng, reqs, **run_kw):
    res = eng.run(reqs, speculative=True, **run_kw)
    for i, ((toks, n_new), out) in enumerate(zip(reqs, res["outputs"])):
        ref = eng.generate(toks[None], n_new)["tokens"][0]
        np.testing.assert_array_equal(out, ref, err_msg=f"request {i}")
    return res


# --------------------------------------------------- rollback primitive
def test_block_tables_truncate_to_frees_tail_only():
    bt = paged_kv.BlockTables(2, 5)
    bt.append(0, [5, 7, 3, 9])
    assert bt.truncate_to(0, 2) == [3, 9]
    assert bt.as_array()[0].tolist() == [5, 7, 0, 0, 0]
    assert bt.n_blocks(0) == 2 and bt.n_live(0) == 2
    assert bt.truncate_to(0, 2) == []              # idempotent
    bt.append(0, [4])                              # growth continues
    assert bt.as_array()[0].tolist() == [5, 7, 4, 0, 0]
    assert bt.release(0) == [5, 7, 4]
    with pytest.raises(ValueError):
        bt.truncate_to(0, -1)


def test_truncate_to_keeps_reclaimed_placeholders_in_prefix():
    """Out-of-window holes (free_prefix) and speculative tail rollback
    compose: truncation only touches the tail, placeholders stay put so
    logical block indices never shift."""
    bt = paged_kv.BlockTables(1, 6)
    bt.append(0, [5, 7, 3, 9, 2])
    assert bt.free_prefix(0, 2) == [5, 7]          # window reclamation
    assert bt.truncate_to(0, 4) == [2]             # spec rollback
    assert bt.as_array()[0].tolist() == [0, 0, 3, 9, 0, 0]
    assert bt.n_blocks(0) == 4 and bt.n_live(0) == 2
    assert bt.release(0) == [3, 9]


# -------------------------------------- scheduler accept/rollback property
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), page_size=st.integers(1, 5),
       draft_k=st.integers(1, 5), prompt_len=st.integers(1, 11),
       n_new=st.integers(2, 12))
def test_plan_rollback_restores_plain_decode_occupancy(
        seed, page_size, draft_k, prompt_len, n_new):
    """Random draft agreement x k x page boundaries, no model in the loop:
    after every verify step's record + rollback, the lane holds *exactly*
    ``pages_needed(pos)`` pages -- the plain-decode state -- and the run
    ends with every page back on the free list."""
    rng = np.random.default_rng(seed)
    total = pages_needed(prompt_len + n_new - 1, page_size)
    alloc = PageAllocator(total + 3)               # headroom never binds
    n_alloc = alloc.n_free
    sched = Scheduler(1, page_size, total, alloc)
    sched.submit(Request(0, np.zeros(prompt_len, np.int32), n_new=n_new))
    assert sched.try_admit_chunked(prompt_len) is not None
    plan = sched.plan_step(prompt_len, prompt_len + 1)  # whole prompt
    assert plan["sample"] == [0] and plan["spec"] == {}
    sched.record_first(0, 1)
    while sched.has_work:
        plan = sched.plan_step(1, draft_k + 1, draft_k=draft_k)
        s = sched.slot(0)
        pos0 = s.pos
        cols = plan["spec"][0]
        remaining = n_new - len(s.out)
        assert 1 <= cols <= min(draft_k + 1, remaining)
        # positions pos0..pos0+cols-1 are planned and page-backed
        assert plan["positions"][0, :cols].tolist() == \
            list(range(pos0, pos0 + cols))
        assert sched.tables.n_live(0) == pages_needed(pos0 + cols, page_size)
        # random agreement: accept a of the cols-1 drafts, emit a+1 tokens
        a = int(rng.integers(0, cols))
        done = False
        for _ in range(a + 1):
            done = sched.record(0, 7)
        if done:
            break
        sched.rollback_speculation(0)
        # the no-leak invariant: exactly the plain-decode page set remains
        assert sched.tables.n_live(0) == pages_needed(s.pos, page_size)
        assert alloc.n_free == n_alloc - sched.tables.n_live(0)
    assert alloc.n_free == n_alloc                 # finished: all freed


def test_plan_step_sheds_draft_tail_for_mandatory_decode_token():
    """A lane's optional draft-span pages must never starve another lane's
    mandatory feedback token: when the free list runs dry mid-plan and no
    prefilling slot is left to preempt, the widest span's freshly granted
    draft-tail page is shed (speculation degrades; plain decode never
    fails where it would have succeeded without speculation)."""
    # page_size=2, 4 usable pages.  Two prompt-2 / n_new-6 requests: one
    # admission block each (free: 2), then both decode from pos 2.
    sched = Scheduler(2, 2, 4, PageAllocator(5))
    for rid in (0, 1):
        sched.submit(Request(rid, np.zeros(2, np.int32), n_new=6))
        assert sched.try_admit_chunked(2) is not None
    plan = sched.plan_step(2, 8)                   # both whole prompts
    assert sorted(plan["sample"]) == [0, 1]
    sched.record_first(0, 1)
    sched.record_first(1, 1)
    # draft_k=3: lane0 plans span 4 (pos 2..5 -> blocks 1+2, both fresh,
    # free list now empty); lane1's mandatory pos-2 block then sheds
    # lane0's block-2 tail page -- lane0 degrades to 2 columns, lane1
    # gets its block and degrades at its own block-2 boundary
    plan = sched.plan_step(1, 8, draft_k=3)
    assert plan["spec"] == {0: 2, 1: 2}
    assert plan["requeued"] == []                  # nobody was preempted
    assert sched.allocator.n_free == 0
    for i, cols in plan["spec"].items():
        s = sched.slot(i)
        assert s.pos == 2 and cols == 2
        # every planned column is page-backed, none beyond
        assert sched.tables.n_live(i) == pages_needed(s.pos + cols, 2)
        np.testing.assert_array_equal(plan["positions"][i, :cols], [2, 3])
        assert (plan["positions"][i, cols:] == paged_kv.POS_SENTINEL).all()
        np.testing.assert_array_equal(plan["logit_cols"][i], [0, 1, 1, 1])
    # the shed page is NOT in the scrub set (it is back on the free list)
    assert len(plan["fresh"]) == 2 and len(set(plan["fresh"])) == 2


# ------------------------------------------------------- engine parity
def test_spec_run_matches_generate_and_bounded_traces():
    """Greedy speculative run() == independent generate() per request, the
    full-depth self-draft pins the acceptance ceiling, and jit variants
    stay bounded (2 model_step + 2 draft_step per run, no batch-1
    prefill)."""
    cfg, eng = _engine("internlm2-20b", max_len=32, attn_impl="ref")
    reqs = _requests(cfg.vocab, MIXED)
    res = eng.run(reqs, page_size=4, max_slots=3, speculative=True,
                  draft_k=3)
    counts = dict(eng.trace_counts)     # before the generate() refs below
    st_ = res["stats"]
    assert st_.mode == "chunked"
    assert st_.spec_steps > 0 and st_.draft_proposed > 0
    assert st_.tokens_out == sum(n for _, n in MIXED)
    assert counts["model_step"] <= 2    # verify/mixed width + pure decode
    assert counts["draft_step"] <= 2    # mirror width + (R, 1) proposals
    assert counts.get("prefill", 0) == 0
    for (toks, n_new), out in zip(reqs, res["outputs"]):
        np.testing.assert_array_equal(
            out, eng.generate(toks[None], n_new)["tokens"][0])

    # draft == target: every draft accepted, tokens/lane-step caps at k+1
    eng.trace_counts.clear()
    res = _assert_spec_matches_generate(
        eng, reqs, page_size=4, max_slots=3, draft_k=3,
        draft_layers=cfg.n_repeat)
    st_ = res["stats"]
    assert st_.acceptance_rate == 1.0
    assert 1.0 < st_.spec_tokens_per_step <= 4.0
    assert eng.trace_counts["draft_step"] <= 2


@pytest.mark.slow
def test_spec_accounting_excludes_rejected_drafts():
    """Rejected draft tokens exist only in draft_proposed/draft_accepted:
    tokens_out, TTFT and the decode rate see emitted tokens alone, and the
    per-request histogram sums to the lane's verify steps."""
    cfg, eng = _engine("internlm2-20b", max_len=32, attn_impl="ref")
    reqs = _requests(cfg.vocab, MIXED[:4], seed=9)
    res = eng.run(reqs, page_size=4, max_slots=4, speculative=True,
                  draft_k=2)                      # shallow draft: rejections
    st_ = res["stats"]
    assert st_.tokens_out == sum(n for _, n in MIXED[:4])
    assert st_.draft_accepted <= st_.draft_proposed
    assert st_.spec_tokens_out == st_.draft_accepted + st_.spec_lane_steps
    assert sorted(st_.ttft_steps) == [0, 1, 2, 3]
    assert all(v >= 1 for v in st_.ttft_steps.values())
    # histogram: one entry per lane-step, accepted counts within [0, k]
    assert sum(n for h in st_.accepted_hist.values()
               for n in h.values()) == st_.spec_lane_steps
    assert all(0 <= a <= 2 for h in st_.accepted_hist.values() for a in h)


def test_spec_rejects_hybrid_pattern_with_monolithic_hint():
    """Satellite fix: recurrent/memory caches cannot run the multi-token
    verify chunk -- speculative=True on a hybrid pattern fails fast with
    an error naming the monolithic fallback, before any model call."""
    cfg, eng = _engine("jamba-1.5-large-398b", max_len=16)
    reqs = _requests(cfg.vocab, [(3, 2)], seed=1)
    with pytest.raises(ValueError, match="monolithic"):
        eng.run(reqs, page_size=4, max_slots=1, speculative=True)
    # and the guard fires for the forced-monolithic combination too
    cfg2, eng2 = _engine("internlm2-20b", max_len=16)
    with pytest.raises(ValueError, match="chunked"):
        eng2.run(_requests(cfg2.vocab, [(3, 2)]), page_size=4, max_slots=1,
                 prefill="monolithic", speculative=True)


def test_spec_argument_validation():
    cfg, eng = _engine("internlm2-20b", max_len=16)
    reqs = _requests(cfg.vocab, [(3, 2)])
    with pytest.raises(ValueError, match="draft_k"):
        eng.run(reqs, speculative=True, draft_k=0)
    with pytest.raises(ValueError, match="draft_policy"):
        eng.run(reqs, speculative=True, draft_policy="oracle")
    with pytest.raises(ValueError, match="draft_layers"):
        eng.run(reqs, speculative=True, draft_policy="lowbit",
                draft_layers=1)
    with pytest.raises(ValueError, match="draft_layers"):
        eng.run(reqs, speculative=True, draft_layers=99)
    # knob/policy symmetry: each draft knob is rejected with the other
    # policy instead of being silently ignored
    with pytest.raises(ValueError, match="draft_act_bits"):
        eng.run(reqs, speculative=True, draft_policy="prefix",
                draft_act_bits=2.0)


@pytest.mark.slow
def test_draft_cache_stays_warm_through_degraded_steps(monkeypatch):
    """Regression: steps where page pressure degrades *every* span to
    width 1 (and no chunks run) must still feed decode feedback tokens
    through the draft -- skipping the pass would leave draft-cache holes
    the 1-token catch-up can never repair, permanently cratering
    acceptance.  Simulate the squeeze at the plan level: a self-agreeing
    draft must keep acceptance at 1.0 across it."""
    from repro.serve.scheduler import Scheduler
    cfg, eng = _engine("internlm2-20b", max_len=64, attn_impl="ref")
    orig = Scheduler.plan_step
    state = {"step": 0}

    def squeezed(self, chunk, budget, draft_k=0):
        plan = orig(self, chunk, budget, draft_k=draft_k)
        state["step"] += 1
        if draft_k and 3 <= state["step"] <= 5:
            for i, cols in list(plan["spec"].items()):
                if cols > 1:      # degrade the span, keep the pages (the
                    plan["spec"][i] = 1        # lane grows into them)
                    plan["positions"][i, 1:] = paged_kv.POS_SENTINEL
                    plan["logit_cols"][i] = 0
        return plan

    monkeypatch.setattr(Scheduler, "plan_step", squeezed)
    reqs = _requests(cfg.vocab, [(4, 24)], seed=3)
    res = _assert_spec_matches_generate(eng, reqs, page_size=4, max_slots=1,
                                        draft_k=3,
                                        draft_layers=cfg.n_repeat)
    st_ = res["stats"]
    assert st_.acceptance_rate == 1.0, dict(st_.accepted_hist)


# ----------------------------------------------- engine parity, @slow
@pytest.mark.slow
def test_spec_matches_generate_window_int8_lowbit_pallas():
    """The hardest parity cell: sliding-window arch, int8 KV pages, the
    low-bit AutoQ-native draft, Pallas kernels -- verify spans cross page
    and window boundaries and the stream still bit-matches the oracle."""
    cfg, eng = _engine("gemma2-2b", max_len=32, kv_bits=8)  # attn=pallas
    reqs = _requests(cfg.vocab, MIXED[:4], seed=21)
    res = _assert_spec_matches_generate(eng, reqs, page_size=4, max_slots=3,
                                        draft_k=3, draft_policy="lowbit")
    assert res["stats"].spec_steps > 0


@pytest.mark.slow
def test_spec_sampled_streams_match_plain_run():
    """temperature > 0: each emitted token is sampled with the same rng
    split + logits plain decode would use (rejected columns consume no
    rng), so even sampled streams are bit-identical to the
    non-speculative run."""
    cfg, eng = _engine("internlm2-20b", max_len=32, attn_impl="ref")
    rng = np.random.default_rng(2)
    reqs = [{"tokens": rng.integers(0, cfg.vocab, size=s).astype(np.int32),
             "n_new": n, "temperature": t, "seed": 40 + i}
            for i, (s, n, t) in enumerate(
                [(3, 6, 0.8), (9, 4, 0.0), (5, 5, 1.2), (2, 6, 0.5)])]
    plain = eng.run(reqs, page_size=4, max_slots=4)
    spec = eng.run(reqs, page_size=4, max_slots=4, speculative=True,
                   draft_k=3, draft_layers=cfg.n_repeat)
    for i, (a, b) in enumerate(zip(plain["outputs"], spec["outputs"])):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), draft_k=st.integers(1, 4),
       flip=st.sampled_from([0.0, 0.3, 0.7, 1.0]),
       arch=st.sampled_from(["internlm2-20b", "gemma2-2b"]))
def test_spec_parity_under_random_draft_agreement(seed, draft_k, flip, arch):
    """Random draft agreement patterns at the engine level: a full-depth
    (perfectly agreeing) draft corrupted token-wise with probability
    ``flip`` yields arbitrary accept/reject prefixes, and the emitted
    stream still bit-equals the oracle while the pool drains clean."""
    cfg, eng = _engine(arch, max_len=32, attn_impl="ref")
    rng = np.random.default_rng(seed)
    orig = eng._draft_propose

    def noisy(spec, plan, sched, spec_lanes, w1):
        drafts = orig(spec, plan, sched, spec_lanes, w1)
        for d in drafts.values():
            mask = rng.random(d.shape) < flip
            d[mask] = rng.integers(0, cfg.vocab, int(mask.sum()),
                                   dtype=np.int32)
        return drafts

    eng._draft_propose = noisy
    reqs = _requests(cfg.vocab, MIXED[:4], seed=seed % 1000)
    res = _assert_spec_matches_generate(eng, reqs, page_size=4, max_slots=2,
                                        draft_k=draft_k,
                                        draft_layers=cfg.n_repeat)
    st_ = res["stats"]
    if flip == 0.0:
        assert st_.acceptance_rate == 1.0
    assert st_.tokens_out == sum(n for _, n in MIXED[:4])


# --------------------------------------- all-local window + speculation
@pytest.mark.slow
def test_spec_with_out_of_window_reclamation():
    """Speculative spans and O(window) page reclamation compose: a long
    all-local generation speculates, rolls back, reclaims, and still
    reproduces the oracle in a pool far smaller than its history."""
    base = ARCHS["gemma2-2b"].smoke
    cfg = dc.replace(base, pattern=(base.pattern[0], base.pattern[0]),
                     window=8)
    model = LM(cfg)
    params = model.init(KEY)
    eng = ServeEngine(model, params, max_len=64, attn_impl="ref")
    toks = _requests(cfg.vocab, [(4, 40)], seed=31)[0][0]
    ref = eng.generate(toks[None], 40)["tokens"][0]
    res = eng.run([(toks, 40)], page_size=4, max_slots=1, num_pages=9,
                  speculative=True, draft_k=3, draft_layers=cfg.n_repeat)
    np.testing.assert_array_equal(res["outputs"][0], ref)
    st_ = res["stats"]
    assert st_.reclaimed_pages > 0
    assert st_.spec_tokens_per_step > 1.0
    # in-window blocks + speculation lookahead stay O(window + draft_k)
    assert st_.peak_pages <= 5
