"""Paged KV cache + continuous-batching scheduler.

The acceptance contract: with concurrent mixed-length requests,
``ServeEngine.run`` emits token streams identical per request to independent
single-request ``generate`` calls (the dense-cache oracle), for the raw,
fake-quant, and packed weight stores alike -- the paged pool and the
scheduler must be invisible to the numerics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import LM
from repro.quant.policy import QuantPolicy
from repro.serve import (PageAllocator, PagesExhausted, Request, Scheduler,
                         ServeEngine, pages_needed)
from repro.serve import paged_kv

KEY = jax.random.PRNGKey(0)

# (prompt_len, n_new) workloads covering page-aligned and ragged prompts,
# staggered finish times, and more requests than decode slots
MIXED_8 = [(3, 5), (7, 4), (5, 6), (9, 3), (2, 5), (6, 4), (8, 5), (4, 6)]


def _requests(vocab, shapes, seed=3):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, vocab, size=s).astype(np.int32), n)
            for s, n in shapes]


def _engine(arch_id, **kw):
    cfg = ARCHS[arch_id].smoke
    model = LM(cfg)
    params = model.init(KEY)
    return cfg, ServeEngine(model, params, **kw)


def _assert_run_matches_generate(eng, reqs, **run_kw):
    res = eng.run(reqs, **run_kw)
    assert len(res["outputs"]) == len(reqs)
    for i, ((toks, n_new), out) in enumerate(zip(reqs, res["outputs"])):
        ref = eng.generate(toks[None], n_new)["tokens"][0]
        np.testing.assert_array_equal(out, ref, err_msg=f"request {i}")
    return res


# ------------------------------------------------------------- page allocator
def test_allocator_free_list_reuse_and_trash_reservation():
    a = PageAllocator(6)                       # pages 1..5 allocatable
    assert a.n_free == 5
    first = a.alloc(3)
    assert 0 not in first and len(set(first)) == 3
    a.free(first[:2])
    again = a.alloc(4)                         # reuses the two freed pages
    assert 0 not in again and set(again).isdisjoint({first[2]})
    assert a.n_free == 0
    with pytest.raises(PagesExhausted):
        a.alloc(1)


def test_allocator_rejects_double_free_and_bad_ids():
    a = PageAllocator(4)
    p = a.alloc(1)
    a.free(p)
    with pytest.raises(ValueError):
        a.free(p)                              # double free
    with pytest.raises(ValueError):
        a.free([0])                            # trash page


def test_pages_needed():
    assert pages_needed(0, 4) == 0
    assert pages_needed(1, 4) == 1
    assert pages_needed(4, 4) == 1
    assert pages_needed(5, 4) == 2


def test_block_tables_map_and_release():
    bt = paged_kv.BlockTables(2, 3)
    bt.append(0, [5, 7])
    arr = bt.as_array()
    assert arr[0].tolist() == [5, 7, paged_kv.TRASH_PAGE]
    assert arr[1].tolist() == [0, 0, 0]
    assert bt.release(0) == [5, 7]
    assert bt.as_array()[0].tolist() == [0, 0, 0]
    with pytest.raises(ValueError):
        bt.append(1, [1, 2, 3, 4])             # exceeds blocks_per_seq


# --------------------------------------------------------------- scheduler
def test_scheduler_admission_backpressure_and_fifo():
    """The queue head waits for pages; later requests never jump it."""
    sched = Scheduler(n_slots=4, page_size=4, blocks_per_seq=4,
                      allocator=PageAllocator(4))     # 3 allocatable pages
    big = Request(0, np.zeros(12, np.int32), n_new=4)     # needs 3 + headroom
    small = Request(1, np.zeros(2, np.int32), n_new=2)
    sched.submit(big)
    sched.submit(small)
    assert sched.try_admit() is None           # 3 free < min(3+1, 4): waits
    assert sched.has_work                      # and small stays behind it
    sched2 = Scheduler(n_slots=1, page_size=4, blocks_per_seq=4,
                       allocator=PageAllocator(8))
    sched2.submit(Request(0, np.zeros(5, np.int32), n_new=2))
    sched2.submit(Request(1, np.zeros(2, np.int32), n_new=2))
    req, slot, pages = sched2.try_admit()
    assert req.rid == 0 and len(pages) == 2
    assert sched2.try_admit() is None          # single slot occupied...
    assert not sched2.bind(slot, req, first_token=7)
    assert sched2.record(slot, 9)              # n_new=2 reached: releases
    assert sched2.allocator.n_free == 7        # pages returned to free list
    req2, slot2, _ = sched2.try_admit()        # ...and the queue drains
    assert req2.rid == 1 and slot2 == slot


def test_scheduler_idle_lanes_carry_sentinel_pos():
    """Idle decode lanes must write with sentinel positions: a real pos
    written to the trash page would surface as a fake attendable KV entry
    in every active sequence's unmapped blocks."""
    sched = Scheduler(n_slots=2, page_size=4, blocks_per_seq=2,
                      allocator=PageAllocator(5))
    sched.submit(Request(0, np.zeros(3, np.int32), n_new=3))
    req, slot, _ = sched.try_admit()
    sched.bind(slot, req, first_token=1)
    b = sched.batch()
    idle = 1 - slot
    assert b["pos"][idle] == paged_kv.POS_SENTINEL
    assert (b["block_tables"][idle] == paged_kv.TRASH_PAGE).all()
    assert b["pos"][slot] == 3


def test_plan_step_decode_preempting_later_slot_survives_iteration():
    """Regression: a decode lane at a page boundary that preempts a
    prefilling slot at a *later* index must not crash plan_step when the
    stale running-slot snapshot reaches the vacated entry.  This is
    exactly the memory-pressure scenario preempt-and-requeue exists for."""
    sched = Scheduler(n_slots=2, page_size=4, blocks_per_seq=4,
                      allocator=PageAllocator(4))        # 3 usable pages
    a = Request(0, np.arange(4, dtype=np.int32), n_new=6)
    sched.submit(a)
    assert sched.try_admit_chunked(4) is not None        # 1 page, 2 free
    plan = sched.plan_step(4, 8)                         # full prompt chunk
    assert plan["sample"] == [0]
    sched.record_first(0, 11)
    b = Request(1, np.arange(8, dtype=np.int32), n_new=2)
    sched.submit(b)
    assert sched.try_admit_chunked(4) is not None        # 1 page, 1 free
    # budget 1: the decode lane takes it all, slot 1 idles mid-prefill
    for _ in range(4):                                   # pos 4 -> 8
        plan = sched.plan_step(4, 1)
        assert plan["sample"] == [0] and not plan["requeued"]
        sched.record(0, 7)
    # slot 0's pos=8 needs a 3rd page, pool empty: slot 1 (later index,
    # prefilling) is preempted -- the loop must skip its vacated entry
    assert sched.allocator.n_free == 0
    plan = sched.plan_step(4, 1)
    assert plan["sample"] == [0] and plan["requeued"] == [1]
    assert len(plan["freed"]) == 1              # B's admission page reported
    assert sched.running_slots() == [0]
    sched.record(0, 7)
    # the preempted request is back at the queue head, re-admittable
    assert sched.try_admit_chunked(4) is not None


def test_plan_step_partial_chunk_preemption_keeps_fresh_pages_live():
    """Regression: when a chunk is partially backed before PagesExhausted,
    the pages allocated for it this step are freed by the preemption and
    must NOT appear in ``fresh`` -- the engine would scrub free-listed
    (possibly re-allocated) pages."""
    sched = Scheduler(n_slots=2, page_size=2, blocks_per_seq=8,
                      allocator=PageAllocator(5))        # 4 usable pages
    a = Request(0, np.arange(2, dtype=np.int32), n_new=6)
    sched.submit(a)
    assert sched.try_admit_chunked(2) is not None        # 1 page, 3 free
    plan = sched.plan_step(2, 8)
    assert plan["sample"] == [0]
    sched.record_first(0, 5)
    b = Request(1, np.arange(8, dtype=np.int32), n_new=2)
    sched.submit(b)
    assert sched.try_admit_chunked(2) is not None        # 1 page, 2 free
    plan = sched.plan_step(2, 8)                         # A +1 page, B pos=2
    assert plan["chunked"] == {1: 2} and sched.allocator.n_free == 1
    sched.record(0, 7)
    # B's next chunk spans blocks 1..2: block 1 allocs (pool now empty),
    # block 2 raises -- B is preempted and the block-1 page freed with it
    plan = sched.plan_step(4, 8)
    assert plan["requeued"] == [1]
    assert plan["fresh"] == []                           # nothing free-listed
    assert len(plan["freed"]) == 2                       # and both reported
    assert sched.allocator.n_free == 2                   # B's 2 pages back


def test_run_pool_too_small_raises():
    cfg, eng = _engine("internlm2-20b", max_len=32)
    reqs = _requests(cfg.vocab, [(12, 4)])
    with pytest.raises(PagesExhausted):
        # 2 pages total (1 usable after trash): prompt alone needs 3
        eng.run(reqs, page_size=4, max_slots=1, num_pages=3)


# ------------------------------------------------- engine parity (tentpole)
def test_run_matches_8_independent_generates_dense_arch():
    """Acceptance: 8 concurrent mixed-length requests through the paged
    engine == 8 independent single-request generate calls, while the
    decode batch actually interleaves (fewer batched steps than the serial
    sum of per-request steps)."""
    cfg, eng = _engine("internlm2-20b", max_len=32)
    reqs = _requests(cfg.vocab, MIXED_8)
    res = _assert_run_matches_generate(eng, reqs, page_size=4, max_slots=8)
    assert res["stats"].tokens_out == sum(n for _, n in MIXED_8)
    serial_steps = sum(n - 1 for _, n in MIXED_8)
    assert res["stats"].steps < serial_steps   # interleaving, not serial


def test_run_matches_generate_sliding_window_arch():
    """local_attn blocks: the paged pool keeps all positions and relies on
    the window mask, where the dense oracle keeps a ring buffer -- both
    must attend to exactly the last `window` positions."""
    cfg, eng = _engine("gemma2-2b", max_len=32)
    assert cfg.window is not None
    reqs = _requests(cfg.vocab, MIXED_8)
    _assert_run_matches_generate(eng, reqs, page_size=4, max_slots=4)


def test_run_more_requests_than_slots_reuses_pages():
    """Waves through 2 slots: released pages/slots are recycled mid-run and
    late admissions still reproduce the oracle."""
    cfg, eng = _engine("internlm2-20b", max_len=32)
    reqs = _requests(cfg.vocab, MIXED_8[:6], seed=11)
    # pool sized for the 2 slots only: later waves MUST reuse freed pages
    res = _assert_run_matches_generate(eng, reqs, page_size=4, max_slots=2,
                                       num_pages=2 * pages_needed(32, 4) + 1)
    assert res["stats"].n_requests == 6


@pytest.mark.slow
def test_run_matches_generate_hybrid_mamba_moe_arch():
    """jamba smoke: recurrent (slot-indexed) mamba state + attn + MoE ride
    the paged engine via the cache_kinds dispatch (auto-falling back to
    monolithic prefill: state blocks cannot chunk)."""
    cfg, eng = _engine("jamba-1.5-large-398b", max_len=32)
    reqs = _requests(cfg.vocab, [(4, 4), (6, 3), (3, 5), (5, 4)], seed=7)
    res = _assert_run_matches_generate(eng, reqs, page_size=4, max_slots=2)
    assert res["stats"].mode == "monolithic"


def _mixed_policy(model, seed=0):
    graph = model.graph(seq_len=4, batch=2)
    policy = QuantPolicy.uniform(graph, 4.0)
    rng = np.random.default_rng(seed)
    for l in graph.layers:
        policy.weight_bits[l.name] = rng.choice(
            [2, 3, 4, 4, 8], size=l.n_groups).astype(np.float32)
    return graph, policy


@pytest.mark.parametrize("store", [
    "fake",
    # packed matmuls run in Pallas interpret mode on CPU: correct but slow
    pytest.param("packed", marks=pytest.mark.slow),
])
def test_run_matches_generate_quantized_stores(store):
    """Acceptance: both weight stores serve through the paged engine
    unchanged -- run() == generate() per request under a mixed-QBN policy."""
    cfg = ARCHS["gemma2-2b"].smoke
    model = LM(cfg)
    params = model.init(KEY)
    graph, policy = _mixed_policy(model)
    eng = ServeEngine(model, params, policy=policy, graph=graph, max_len=24,
                      weight_store=store)
    reqs = _requests(cfg.vocab, [(3, 4), (6, 3), (5, 4), (2, 5), (7, 3),
                                 (4, 4), (8, 3), (3, 5)], seed=5)
    _assert_run_matches_generate(eng, reqs, page_size=4, max_slots=8)


def test_run_pallas_and_ref_engines_emit_identical_streams():
    """The attention backend is invisible to the token streams: an engine on
    the Pallas kernels (the default) reproduces the jnp-oracle engine
    token for token on the same workload."""
    cfg = ARCHS["internlm2-20b"].smoke
    model = LM(cfg)
    params = model.init(KEY)
    reqs = _requests(cfg.vocab, MIXED_8)
    eng_p = ServeEngine(model, params, max_len=32)          # attn_impl=pallas
    assert eng_p.attn_impl == "pallas"
    eng_r = ServeEngine(model, params, max_len=32, attn_impl="ref")
    res_p = eng_p.run(reqs, page_size=4, max_slots=8)
    res_r = eng_r.run(reqs, page_size=4, max_slots=8)
    for i, (a, b) in enumerate(zip(res_p["outputs"], res_r["outputs"])):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")


@pytest.mark.parametrize("arch_id", ["internlm2-20b", "gemma2-2b"])
def test_run_matches_generate_int8_paged_kv(arch_id):
    """kv_bits=8: the paged pool stores int8 pages + scale pages with the
    same quantizer as the dense int8 cache, so run() == generate() stays
    bit-exact (the Pallas decode kernel dequantizes pages in VMEM)."""
    cfg, eng = _engine(arch_id, max_len=32, kv_bits=8)
    reqs = _requests(cfg.vocab, MIXED_8[:6], seed=9)
    _assert_run_matches_generate(eng, reqs, page_size=4, max_slots=4)


@pytest.mark.slow
def test_run_matches_generate_bf16_paged_kv():
    """cache_dtype=bfloat16: dense prefill attends the cache-dtype round
    trip of the in-flight K/V (the values the chunked path reads back from
    bf16 pages), so run() == generate() holds for narrow fp caches in both
    prefill modes, like it does for f32 and int8."""
    cfg, eng = _engine("internlm2-20b", max_len=32,
                       cache_dtype=jnp.bfloat16, attn_impl="ref")
    reqs = _requests(cfg.vocab, MIXED_8[:4], seed=41)
    for mode in ("chunked", "monolithic"):
        _assert_run_matches_generate(eng, reqs, page_size=4, max_slots=2,
                                     prefill=mode)


def test_serve_act_bits_threaded_not_dropped():
    """A policy's activation QBNs must reach the serve path: aggressive act
    quantization has to change the served stream vs serve_act_bits=False
    (the pre-refactor behavior, kept as the escape hatch)."""
    cfg = ARCHS["internlm2-20b"].smoke
    model = LM(cfg)
    params = model.init(KEY)
    graph = model.graph(seq_len=4, batch=2)
    policy = QuantPolicy.uniform(graph, 8.0, act_bits=2.0)
    on = ServeEngine(model, params, policy=policy, graph=graph, max_len=32)
    off = ServeEngine(model, params, policy=policy, graph=graph, max_len=32,
                      serve_act_bits=False)
    assert on.act_bits is not None and off.act_bits is None
    assert float(on.act_bits[0, 0]) == 2.0
    toks = _requests(cfg.vocab, [(6, 8)], seed=13)[0][0]
    out_on = on.generate(toks[None], 8)["tokens"]
    out_off = off.generate(toks[None], 8)["tokens"]
    assert not np.array_equal(out_on, out_off)
    # and the paged path applies the very same act quantization (parity)
    _assert_run_matches_generate(on, [(toks, 8)], page_size=4, max_slots=2)


def test_run_request_forms_and_sampling():
    """Dict/tuple/Request inputs coexist; per-request temperature streams
    are independent and in-vocab."""
    cfg, eng = _engine("internlm2-20b", max_len=32)
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
    res = eng.run([
        (toks, 3),
        {"tokens": toks, "n_new": 4, "temperature": 0.8, "seed": 1},
        Request(rid=0, tokens=toks, n_new=2),
    ], page_size=4, max_slots=2)
    assert [len(o) for o in res["outputs"]] == [3, 4, 2]
    for out in res["outputs"]:
        assert (out >= 0).all() and (out < cfg.vocab).all()
    # greedy requests with the same prompt emit identical stream prefixes
    np.testing.assert_array_equal(res["outputs"][0][:2], res["outputs"][2])


def test_run_rejects_oversized_request():
    cfg, eng = _engine("internlm2-20b", max_len=16)
    reqs = _requests(cfg.vocab, [(10, 10)])
    with pytest.raises(ValueError, match="max_len"):
        eng.run(reqs, page_size=4)


# ------------------------------------------- chunked prefill (tentpole)
def test_run_chunked_matches_generate_across_chunk_sizes():
    """The token-budget step loop is invisible to the numerics: any chunk
    size (single token, sub-page, page-crossing; plus partial final chunks)
    reproduces independent generate calls per request.  (chunk ==
    page_size is every default-run parity test in this file.)"""
    cfg, eng = _engine("internlm2-20b", max_len=32)
    reqs = _requests(cfg.vocab, MIXED_8)
    refs = [eng.generate(toks[None], n)["tokens"][0] for toks, n in reqs]
    for chunk in (1, 3, 8):
        res = eng.run(reqs, page_size=4, max_slots=8, prefill="chunked",
                      chunk_tokens=chunk)
        for i, (ref, out) in enumerate(zip(refs, res["outputs"])):
            np.testing.assert_array_equal(out, ref,
                                          err_msg=f"chunk={chunk} req {i}")
        assert res["stats"].mode == "chunked"
        assert res["stats"].chunk_prefill_tokens == \
            sum(s for s, _ in MIXED_8)
        assert res["stats"].mono_prefill_tokens == 0


def test_run_chunked_matches_generate_window_and_int8():
    """Chunk boundaries crossing the sliding window and int8 KV pages at
    once: the hardest parity cell (chunk tokens attend earlier chunks
    through quantized pages exactly as the dense oracle's prefill does)."""
    cfg, eng = _engine("gemma2-2b", max_len=32, kv_bits=8)
    reqs = _requests(cfg.vocab, MIXED_8[:4], seed=21)
    _assert_run_matches_generate(eng, reqs, page_size=4, max_slots=3,
                                 prefill="chunked", chunk_tokens=3)


def test_run_monolithic_mode_still_matches_generate():
    """The legacy batch-1 prefill path stays available (hybrid archs, TTFT
    baseline) and stays parity-gated."""
    cfg, eng = _engine("internlm2-20b", max_len=32)
    reqs = _requests(cfg.vocab, MIXED_8[:3], seed=17)
    res = _assert_run_matches_generate(eng, reqs, page_size=4, max_slots=2,
                                       prefill="monolithic")
    assert res["stats"].mode == "monolithic"
    assert res["stats"].mono_prefill_tokens == \
        sum(s for s, _ in MIXED_8[:3])
    assert res["stats"].chunk_prefill_tokens == 0


def test_run_token_budget_tight_and_validated():
    """A budget of exactly max_slots still makes >= 1 chunk token of
    progress per step (decode lanes first, leftovers fund chunks), and
    invalid budgets/chunk sizes are rejected up front."""
    cfg, eng = _engine("internlm2-20b", max_len=32)
    reqs = _requests(cfg.vocab, MIXED_8[:4], seed=19)
    res = _assert_run_matches_generate(eng, reqs, page_size=4, max_slots=2,
                                       prefill="chunked", chunk_tokens=4,
                                       token_budget=3)
    assert set(res["stats"].ttft_steps) == {r for r in range(4)}
    with pytest.raises(ValueError, match="token_budget"):
        eng.run(reqs, page_size=4, max_slots=4, token_budget=2)
    with pytest.raises(ValueError, match="chunk_tokens"):
        eng.run(reqs, page_size=4, max_slots=2, chunk_tokens=0)


def test_run_chunked_rejects_hybrid_pattern():
    """Recurrent (mamba) state cannot chunk: forcing chunked on a hybrid
    arch fails fast, before any model call.  (Auto fallback to monolithic
    is asserted in the slow hybrid parity test.)"""
    cfg, eng = _engine("jamba-1.5-large-398b", max_len=16)
    reqs = _requests(cfg.vocab, [(3, 2)], seed=1)
    with pytest.raises(ValueError, match="chunk"):
        eng.run(reqs, page_size=4, max_slots=1, prefill="chunked")


def test_run_chunked_requeues_instead_of_failing_mid_admission():
    """Satellite fix: with chunked admission a prefilling sequence that
    cannot grow its pages is preempted and requeued (not an exception), and
    its restarted stream is identical to the oracle."""
    cfg, eng = _engine("internlm2-20b", max_len=32)
    # two 12-token prompts, pool of 6 usable pages (page_size 4): each
    # prompt alone needs 3 pages + headroom, so both admit on first-chunk
    # availability but cannot both finish prefill -- one must requeue
    reqs = _requests(cfg.vocab, [(12, 4), (12, 4)], seed=23)
    res = _assert_run_matches_generate(eng, reqs, page_size=4, max_slots=2,
                                       num_pages=7, prefill="chunked",
                                       chunk_tokens=4)
    assert res["stats"].requeues >= 1
    assert res["stats"].steps > 0


def test_run_chunked_pool_too_small_still_raises():
    """Requeueing never helps a request that can never fit alone: the
    honest PagesExhausted diagnosis survives the chunked refactor."""
    cfg, eng = _engine("internlm2-20b", max_len=32)
    reqs = _requests(cfg.vocab, [(12, 4)])
    with pytest.raises(PagesExhausted):
        eng.run(reqs, page_size=4, max_slots=1, num_pages=3,
                prefill="chunked")


def test_jit_trace_count_independent_of_prompt_lengths():
    """Regression (satellite): serving N distinct prompt lengths through
    the chunked loop traces model_step a constant number of times -- the
    per-prompt-length variant explosion cannot come back -- and never
    touches the retired batch-1 prefill path."""
    cfg = ARCHS["internlm2-20b"].smoke
    model = LM(cfg)
    params = model.init(KEY)

    def serve(shapes):
        eng = ServeEngine(model, params, max_len=32)
        eng.run(_requests(cfg.vocab, shapes, seed=29), page_size=4,
                max_slots=4, prefill="chunked")
        return dict(eng.trace_counts)

    ten = serve([(s, 3) for s in range(2, 12)])      # 10 distinct lengths
    two = serve([(3, 3), (9, 3)])                    # 2 distinct lengths
    assert ten["model_step"] == two["model_step"]
    assert ten["model_step"] <= 2      # mixed-step + pure-decode variants
    assert ten.get("prefill", 0) == 0 and ten.get("decode_step_paged", 0) == 0
    # (the monolithic variant-per-length explosion this retires is gated in
    # benchmarks/continuous_batching.py --smoke, which CI runs)


def _all_local_cfg(window=8):
    import dataclasses as dc
    base = ARCHS["gemma2-2b"].smoke
    return dc.replace(base, pattern=(base.pattern[0], base.pattern[0]),
                      window=window)


def test_out_of_window_pages_reclaimed_occupancy_bounded():
    """Satellite: for an all-sliding-window pattern, pages wholly behind
    the window return to the pool at step boundaries -- occupancy stays
    O(window) and a long generation completes in a pool far smaller than
    its full history (it would exhaust without reclamation) with the token
    stream unchanged."""
    cfg = _all_local_cfg(window=8)
    model = LM(cfg)
    params = model.init(KEY)
    eng = ServeEngine(model, params, max_len=64)
    toks = _requests(cfg.vocab, [(4, 40)], seed=31)[0][0]
    # lifetime positions 4+40-1=43 -> 11 pages of 4; give the pool 6 usable
    res = eng.run([(toks, 40)], page_size=4, max_slots=1, num_pages=7,
                  prefill="chunked")
    ref = eng.generate(toks[None], 40)["tokens"][0]
    np.testing.assert_array_equal(res["outputs"][0], ref)
    st = res["stats"]
    assert st.reclaimed_pages > 0
    # O(window): in-window blocks (ceil(W/ps)+1 for straddle) + 1 growth
    assert st.peak_pages <= 4
    # and the monolithic loop reclaims too (same scheduler hook)
    res_m = eng.run([(toks, 40)], page_size=4, max_slots=1, num_pages=7,
                    prefill="monolithic")
    np.testing.assert_array_equal(res_m["outputs"][0], ref)
    assert res_m["stats"].reclaimed_pages > 0


def test_reclamation_disabled_for_mixed_global_local_pattern():
    """gemma2 alternates local/global blocks; one block table serves every
    layer, so reclaiming for the local blocks would tear KV the global
    blocks still attend -- the engine must not reclaim there."""
    cfg, eng = _engine("gemma2-2b", max_len=32)
    reqs = _requests(cfg.vocab, [(4, 12)], seed=33)
    res = _assert_run_matches_generate(eng, reqs, page_size=4, max_slots=1)
    assert res["stats"].reclaimed_pages == 0


def test_stats_ttft_and_prefill_accounting():
    """Satellite: per-request TTFT (steps + seconds) and chunked-vs-
    monolithic prompt-token accounting are populated on both paths."""
    cfg, eng = _engine("internlm2-20b", max_len=32)
    reqs = _requests(cfg.vocab, MIXED_8[:3], seed=37)
    total_prompt = sum(s for s, _ in MIXED_8[:3])
    for mode in ("chunked", "monolithic"):
        res = eng.run(reqs, page_size=4, max_slots=2, prefill=mode)
        st = res["stats"]
        assert st.mode == mode
        assert sorted(st.ttft_steps) == [0, 1, 2]
        # shared 1-based convention: the index of the model call whose
        # logits produced the first token, in both modes
        assert all(v >= 1 for v in st.ttft_steps.values())
        assert all(v >= 0 for v in st.ttft_s.values())
        fed = (st.chunk_prefill_tokens if mode == "chunked"
               else st.mono_prefill_tokens)
        assert fed == total_prompt
        assert st.ttft_percentiles()[99] >= st.ttft_percentiles()[50]


# ------------------------------------------------------------ paged pool unit
def test_block_tables_free_prefix_keeps_logical_alignment():
    """Reclaimed leading blocks become trash placeholders: later blocks
    keep their logical index, release() frees only live pages."""
    bt = paged_kv.BlockTables(1, 4)
    bt.append(0, [5, 7, 3])
    assert bt.free_prefix(0, 2) == [5, 7]
    assert bt.as_array()[0].tolist() == [0, 0, 3, 0]
    assert bt.n_blocks(0) == 3 and bt.n_live(0) == 1
    assert bt.free_prefix(0, 2) == []          # idempotent
    bt.append(0, [9])                          # growth continues past holes
    assert bt.as_array()[0].tolist() == [0, 0, 3, 9]
    assert bt.release(0) == [3, 9]


def test_scrub_pages_resets_only_named_pages():
    cfg = ARCHS["internlm2-20b"].smoke
    model = LM(cfg)
    cache = model.init_paged_cache(2, 4, 4, dtype=jnp.float32)
    kinds = cfg.cache_kinds()
    # dirty pos everywhere, then scrub page 2 only
    dirty = tuple({**e, "pos": jnp.zeros_like(e["pos"])} for e in cache)
    scrubbed = paged_kv.scrub_pages(dirty, kinds, [2])
    for e in scrubbed:
        assert bool(jnp.all(e["pos"][:, 2] == paged_kv.POS_SENTINEL))
        assert bool(jnp.all(e["pos"][:, [0, 1, 3]] == 0))
