"""Synthetic data pipeline: determinism + learnability signal."""
import numpy as np

from repro.data import SyntheticImages, TokenStream


def test_images_deterministic():
    d = SyntheticImages(img_size=8, seed=3)
    b1, b2 = d.batch(17, 16), d.batch(17, 16)
    np.testing.assert_array_equal(b1["x"], b2["x"])
    np.testing.assert_array_equal(b1["y"], b2["y"])
    b3 = d.batch(18, 16)
    assert not np.array_equal(b1["x"], b3["x"])


def test_images_classes_separable():
    """Class prototypes dominate noise enough to be learnable: a nearest-
    prototype classifier should beat chance by a wide margin."""
    d = SyntheticImages(img_size=8, seed=0)
    protos = d._protos()
    b = d.batch(0, 256)
    flat = b["x"].reshape(256, -1)
    pf = protos.reshape(10, -1)
    pred = np.argmax(flat @ pf.T, axis=1)
    assert (pred == b["y"]).mean() > 0.5


def test_tokens_deterministic_and_structured():
    t = TokenStream(vocab=64, seed=1)
    b1 = t.batch(5, 8, 32)
    b2 = t.batch(5, 8, 32)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # deterministic structure: >= 60% of transitions follow the affine rule
    toks, labs = b1["tokens"], b1["labels"]
    hits = 0
    for a in (1, 3, 5, 7):
        for bb in range(64):
            pred = (a * toks + bb) % 64
            hits = max(hits, (pred == labs).mean(axis=1).max())
    assert hits > 0.6
