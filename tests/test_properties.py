"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.api import SSMCfg
from repro.models.layers import attention, moe_ffn
from repro.models.ssm import _ssd_chunk_scan


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), S=st.integers(3, 24),
       chunk=st.sampled_from([4, 8, 16]))
def test_ssd_chunked_equals_sequential(seed, S, chunk):
    rng = np.random.default_rng(seed)
    B, H, P, N = 2, 2, 3, 4
    xh = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 1.0, size=(B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.3, 1.5, size=(H,)), jnp.float32)

    y, state = _ssd_chunk_scan(xh, Bm, Cm, dt, A, chunk)

    s = np.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        a = np.exp(np.asarray(dt[:, t]) * np.asarray(A))
        s = s * a[..., None, None] + np.einsum(
            "bh,bhp,bn->bhpn", np.asarray(dt[:, t]), np.asarray(xh[:, t]),
            np.asarray(Bm[:, t]))
        ys.append(np.einsum("bn,bhpn->bhp", np.asarray(Cm[:, t]), s))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), s, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), Sq=st.integers(1, 12),
       Skv=st.integers(1, 40), hkv=st.sampled_from([1, 2]),
       g=st.sampled_from([1, 3]))
def test_flash_attention_equals_direct(seed, Sq, Skv, hkv, g):
    """Chunked (flash) path == single-shot softmax attention."""
    if Sq > Skv:
        Sq = Skv
    rng = np.random.default_rng(seed)
    B, D = 2, 8
    Hq = hkv * g
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Skv, hkv, D)), jnp.float32)
    q_pos = jnp.broadcast_to(
        jnp.arange(Skv - Sq, Skv, dtype=jnp.int32)[None], (B, Sq))
    kv_pos = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32)[None],
                              (B, Skv))
    direct = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, chunk=10**9)
    chunked = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, chunk=7)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(chunked),
                               rtol=2e-4, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), T=st.integers(4, 32),
       E=st.sampled_from([2, 4]), K=st.sampled_from([1, 2]))
def test_moe_full_capacity_equals_dense_mixture(seed, T, E, K):
    """With no capacity drops, scatter-dispatch MoE == dense top-k mixture."""
    rng = np.random.default_rng(seed)
    d, ff = 6, 10
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    p = {"router": jnp.asarray(rng.normal(size=(d, E)), jnp.float32),
         "wg": jnp.asarray(rng.normal(size=(E, d, ff)), jnp.float32),
         "wu": jnp.asarray(rng.normal(size=(E, d, ff)), jnp.float32),
         "wd": jnp.asarray(rng.normal(size=(E, ff, d)), jnp.float32)}
    out, probs = moe_ffn(x, p, n_experts=E, top_k=K, capacity_factor=0.0)

    # dense reference: every expert on every token, gated sum of top-k
    logits = x @ p["router"]
    pr = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(pr, K)
    gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
    ref = jnp.zeros_like(x)
    for e in range(E):
        he = jax.nn.silu(x @ p["wg"][e]) * (x @ p["wu"][e])
        ye = he @ p["wd"][e]
        for kk in range(K):
            w = jnp.where(gi[:, kk] == e, gv[:, kk], 0.0)
            ref = ref + ye * w[:, None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.integers(1, 8))
def test_policy_roundtrip_storage(seed, bits):
    """quant -> pack -> dequant stays within the quantization error bound."""
    from repro.quant import quant_pack_int8
    from repro.quant.linear_quant import dequant_int8
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(24, 8)), jnp.float32)
    q, s, _ = quant_pack_int8(w, float(bits), axis=1)
    dq = dequant_int8(q, s)
    amax = np.abs(np.asarray(w)).max(axis=0)
    levels = max(2 ** (bits - 1) - 1, 1)
    bound = amax / levels / 2 + 1e-6
    assert (np.abs(np.asarray(w - dq)) <= bound[None, :] + 1e-6).all()
