"""Integration test of the launch path: jit(step) with production-style
shardings lowers AND compiles on a small multi-device mesh (subprocess with
8 placeholder host devices; the real 256/512-chip runs live in results/).
Covers steps.py + specs.py + sharding/specs.py + the HLO analyzer end-to-end.
"""
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get
from repro.models import LM, shape_by_name
from repro.models.api import ShapeCfg
from repro.optim import AdamW
from repro.launch.steps import (hidden_rules, make_decode_step,
                                make_prefill_step, make_train_step,
                                shardings_for)
from repro.launch.specs import step_structs
from repro.launch.hlo import analyze
from repro.sharding.ctx import sharding_rules
from repro.sharding.specs import to_named
import dataclasses as dc

mesh = jax.make_mesh((2, 4), ("data", "model"))
spec = get("gemma2-2b")
# shrink the production config so the 8-device compile is fast but the
# sharding logic is exercised on the same code path
cfg = dc.replace(spec.config, n_layers=2, d_model=256, n_heads=8,
                 n_kv_heads=4, d_ff=512, vocab=1024, head_dim=32, window=64)
spec = dc.replace(spec, config=cfg)
model = LM(cfg)
opt = AdamW(state_bits=8)

for shape, mode, mk in [
    (ShapeCfg("train_4k", 128, 16, "train"), "train",
     lambda: make_train_step(model, opt)),
    (ShapeCfg("decode_32k", 128, 16, "decode"), "decode",
     lambda: make_decode_step(model)),
]:
    structs = step_structs(spec, shape, opt, cfg_override=cfg)
    in_s, out_s = shardings_for(structs, mode, cfg, shape, mesh)
    with mesh, sharding_rules(mesh, hidden_rules(mesh)):
        compiled = jax.jit(mk(), in_shardings=to_named(in_s, mesh),
                           out_shardings=to_named(out_s, mesh)
                           ).lower(*structs).compile()
    stats = analyze(compiled.as_text(), default_group=8)
    assert stats.flops > 0, mode
    print("OK", mode, int(stats.flops))
"""


def test_launch_path_lowers_and_compiles():
    # JAX_PLATFORMS=cpu keeps jax's TPU plugin from polling GCP metadata
    # (30 HTTP retries per variable) inside the stripped subprocess env
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=500,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert r.stdout.count("OK") == 2
