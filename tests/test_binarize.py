"""Multi-bit binarization tests."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.quant.binarize import (binarize_residual, fake_binarize_per_channel,
                                  reconstruct)

RNG = np.random.default_rng(7)


def test_error_decreases_with_planes():
    w = jnp.asarray(RNG.normal(size=(64, 32)).astype(np.float32))
    errs = []
    for m in (1, 2, 4, 8):
        B, a = binarize_residual(w, m, axis=1)
        errs.append(float(jnp.mean((w - reconstruct(B, a)) ** 2)))
    assert all(x > y for x, y in zip(errs, errs[1:]))


def test_single_plane_is_scaled_sign():
    w = jnp.asarray(RNG.normal(size=(16, 4)).astype(np.float32))
    B, a = binarize_residual(w, 1, axis=1)
    assert set(np.unique(np.asarray(B))) <= {-1, 1}
    assert np.all(np.asarray(a) > 0)


def test_refit_not_worse_than_greedy():
    """The joint LS alpha refit can only improve on greedy alphas."""
    w = jnp.asarray(RNG.normal(size=(64, 8)).astype(np.float32))
    m = 4
    # greedy
    r, greedy = w, jnp.zeros_like(w)
    for _ in range(m):
        b = jnp.where(r >= 0, 1.0, -1.0)
        a = jnp.mean(jnp.abs(r), axis=0, keepdims=True)
        greedy = greedy + a * b
        r = r - a * b
    B, alpha = binarize_residual(w, m, axis=1)
    e_refit = float(jnp.mean((w - reconstruct(B, alpha)) ** 2))
    e_greedy = float(jnp.mean((w - greedy) ** 2))
    assert e_refit <= e_greedy + 1e-6


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), planes=st.integers(0, 8))
def test_fake_binarize_matches_greedy_truncation(seed, planes):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(24, 6)).astype(np.float32))
    out = fake_binarize_per_channel(w, jnp.full(6, float(planes)), axis=1)
    # greedy reference
    r, ref = w, jnp.zeros_like(w)
    for _ in range(planes):
        b = jnp.where(r >= 0, 1.0, -1.0)
        a = jnp.mean(jnp.abs(r), axis=0, keepdims=True)
        ref = ref + a * b
        r = r - a * b
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_heterogeneous_plane_counts():
    # identical data in every channel so per-channel errors are comparable
    col = RNG.normal(size=(32, 1)).astype(np.float32)
    w = jnp.asarray(np.repeat(col, 4, axis=1))
    bits = jnp.asarray([0.0, 1.0, 4.0, 8.0])
    out = fake_binarize_per_channel(w, bits, axis=1)
    assert bool(jnp.all(out[:, 0] == 0))
    errs = [float(jnp.mean((w[:, i] - out[:, i]) ** 2)) for i in (1, 2, 3)]
    assert errs[0] > errs[1] > errs[2]
