"""Compressed gradient all-reduce: exactness vs psum on a multi-device mesh.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main test process keeps the single real CPU device.
"""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.sharding.collectives import compressed_allreduce

mesh = jax.make_mesh((8,), ("pod",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(8, 64, 32)).astype(np.float32))
tiny = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))

def f(g, tiny):
    out = compressed_allreduce({"g": g[0], "t": tiny[0]}, "pod")
    return out["g"], out["t"]

if hasattr(jax, "shard_map"):                     # jax >= 0.6 API
    smap = jax.shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                         out_specs=(P(), P()), axis_names={"pod"},
                         check_vma=False)
else:                                             # jax 0.4.x
    from jax.experimental.shard_map import shard_map
    smap = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                     out_specs=(P(), P()), check_rep=False)
cg, ct = jax.jit(smap)(g, tiny)

exact_g = np.mean(np.asarray(g), axis=0)
exact_t = np.mean(np.asarray(tiny), axis=0)
err = np.abs(np.asarray(cg) - exact_g).max()
scale = np.abs(np.asarray(g)).max(axis=(0, 2), keepdims=True)
# int8 absmax rounding: per-element error <= amax/127/2 per shard, summed
assert err < np.abs(np.asarray(g)).max() / 127.0, err
np.testing.assert_allclose(np.asarray(ct), exact_t, rtol=1e-6, atol=1e-6)
print("OK", err)
"""


def test_compressed_allreduce_subprocess():
    # JAX_PLATFORMS=cpu: without it jax's TPU plugin polls GCP instance
    # metadata (30 HTTP retries per variable) and the subprocess burns the
    # whole timeout before running a single op
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "OK" in r.stdout
