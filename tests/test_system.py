"""End-to-end behaviour tests: the paper's full pipeline at miniature scale.

train CNN -> AutoQ hierarchical search -> best policy -> QAT fine-tune.
Asserts the *relationships* the paper claims (quantized accuracy recovers
with QAT, searched policy beats uniform at equal budget on average bits),
at test-friendly episode counts.  The full 400-episode reproduction lives in
benchmarks/ + EXPERIMENTS.md.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (HierarchicalAgent, QuantEnv, RewardCfg,
                        make_cnn_evaluator, run_search)
from repro.core.ddpg import adam_init, adam_update
from repro.data import SyntheticImages
from repro.models.cnn import CNN, CNNConfig
from repro.quant.policy import QuantPolicy
from repro.train.qat import qat_finetune

CFG = CNNConfig(name="sys", img_size=12, channels=(8, 16, 16),
                pool_after=(0, 1))
DATA = SyntheticImages(img_size=12)

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def trained_cnn():
    model = CNN(CFG)
    params = model.init(jax.random.PRNGKey(0))

    @jax.jit
    def step(params, opt, batch):
        loss, g = jax.value_and_grad(model.loss)(params, batch)
        params, opt = adam_update(params, g, opt, 2e-3)
        return params, opt, loss

    opt = adam_init(params)
    for i in range(120):
        b = {k: jnp.asarray(v) for k, v in DATA.batch(i, 128).items()}
        params, opt, _ = step(params, opt, b)
    val = DATA.batch(99_999, 512)
    acc = float(model.accuracy(
        params, {k: jnp.asarray(v) for k, v in val.items()})) * 100
    assert acc > 60.0, f"substrate CNN failed to train: {acc}"
    return model, params, val, acc


def test_full_autoq_pipeline(trained_cnn):
    model, params, val, full_acc = trained_cnn
    graph = model.graph()
    ev = make_cnn_evaluator(model, params, graph, val)

    env = QuantEnv(graph, params, ev, RewardCfg.accuracy_guaranteed())
    agent = HierarchicalAgent(env, seed=0, updates_per_episode=4)
    res = run_search(agent, n_explore=6, n_exploit=6)

    best = res.best_policy
    assert best is not None
    assert res.best_log.avg_wbits <= 8.0      # searched within the space
    # evaluator consistency: re-evaluating the best policy reproduces its acc
    assert abs(ev(best) - res.best_log.acc) < 1e-3

    # QAT fine-tuning must not make the quantized model worse
    acc_before = ev(best)
    tuned = qat_finetune(model, params, graph, best,
                         lambda i: DATA.batch(1000 + i, 128), steps=30)
    ev_tuned = make_cnn_evaluator(model, tuned, graph, val)
    acc_after = ev_tuned(best)
    assert acc_after >= acc_before - 2.0


def test_searched_beats_uniform_at_lower_bits(trained_cnn):
    """The paper's headline: channel-wise searched policy reaches comparable
    accuracy at lower average bits than a uniform policy."""
    model, params, val, full_acc = trained_cnn
    graph = model.graph()
    ev = make_cnn_evaluator(model, params, graph, val)
    u4 = ev(QuantPolicy.uniform(graph, 4.0))
    u8 = ev(QuantPolicy.uniform(graph, 8.0))
    # sanity of the testbed itself: more bits can't be (much) worse
    assert u8 >= u4 - 2.0
    # a hand-built channel-wise policy (8 bits on high-variance half, 4 on
    # the rest ~ 6 avg) should sit between the uniform points
    from repro.core.env import group_weight_vars
    gv = group_weight_vars(graph, params)
    mixed = QuantPolicy.uniform(graph, 4.0)
    for layer in graph.layers:
        var = gv[layer.name]
        hi = np.argsort(var)[layer.n_groups // 2:]
        mixed.weight_bits[layer.name][hi] = 8.0
    m = ev(mixed)
    assert m >= u4 - 1.0
