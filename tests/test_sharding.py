"""Sharding spec validity for every architecture at production dims.

Every PartitionSpec axis assignment must evenly divide the corresponding
tensor dimension -- checked for params, optimizer state, batches and caches
of all 10 archs without touching device state (shape-level only).
"""
import types

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.launch.specs import (batch_struct, cache_struct, opt_struct,
                                params_struct)
from repro.models import LM, shape_by_name
from repro.optim import AdamW
from repro.sharding import specs as sh

FAKE_MESH = types.SimpleNamespace(shape={"data": 16, "model": 16})
FAKE_MESH_POD = types.SimpleNamespace(shape={"pod": 2, "data": 16,
                                             "model": 16})


def _check(tree_sds, tree_specs, mesh):
    flat_s = jax.tree_util.tree_leaves_with_path(tree_sds)
    flat_p = jax.tree_util.tree_leaves(
        tree_specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for (path, sds), spec in zip(flat_s, flat_p):
        assert isinstance(spec, P), (path, spec)
        for dim, names in zip(sds.shape, tuple(spec)):
            if names is None:
                continue
            names = names if isinstance(names, tuple) else (names,)
            size = 1
            for n in names:
                size *= mesh.shape[n]
            assert dim % size == 0, (path, sds.shape, spec)


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_param_and_opt_specs_divide(arch_id):
    cfg = ARCHS[arch_id].config
    model = LM(cfg)
    p_sds = params_struct(model)
    pspecs = sh.param_specs(p_sds, FAKE_MESH, cfg)
    _check(p_sds, pspecs, FAKE_MESH)
    o_sds = opt_struct(p_sds, AdamW(state_bits=8))
    ospecs = sh.opt_specs(o_sds, pspecs, FAKE_MESH)
    _check(o_sds, ospecs, FAKE_MESH)


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
@pytest.mark.parametrize("shape_name", ["train_4k", "decode_32k",
                                        "long_500k"])
def test_batch_and_cache_specs_divide(arch_id, shape_name):
    spec = ARCHS[arch_id]
    if shape_name in spec.skip_shapes:
        pytest.skip(spec.skip_reason)
    cfg = spec.config
    shp = shape_by_name(shape_name)
    model = LM(cfg)
    b_sds = batch_struct(cfg, shp, shp.mode)
    _check(b_sds, sh.batch_specs(b_sds, FAKE_MESH), FAKE_MESH)
    if shp.mode == "decode":
        c_sds = cache_struct(model, shp.global_batch, shp.seq_len)
        cspecs = sh.cache_specs(c_sds, cfg, FAKE_MESH,
                                long_context=(shape_name == "long_500k"))
        _check(c_sds, cspecs, FAKE_MESH)


def test_multipod_batch_spec():
    cfg = ARCHS["internlm2-20b"].config
    shp = shape_by_name("train_4k")
    b = batch_struct(cfg, shp, "train")
    specs = sh.batch_specs(b, FAKE_MESH_POD)
    assert tuple(specs["tokens"])[0] == ("pod", "data")


def test_expert_weights_get_ep_sharding():
    cfg = ARCHS["jamba-1.5-large-398b"].config
    model = LM(cfg)
    p_sds = params_struct(model)
    pspecs = sh.param_specs(p_sds, FAKE_MESH, cfg)
    # jamba: 16 experts over data=16 (EP), ff over model
    moe_spec = pspecs["blocks"][1]["wg"]
    assert tuple(moe_spec) == (None, "data", None, "model")


def test_granite_odd_expert_count_falls_back():
    cfg = ARCHS["granite-moe-3b-a800m"].config   # 40 experts: not /16
    model = LM(cfg)
    pspecs = sh.param_specs(params_struct(model), FAKE_MESH, cfg)
    e_ax = tuple(pspecs["blocks"][0]["wg"])[1]
    assert e_ax is None                           # replicated expert dim
