"""Fault tolerance: preemption mid-run + auto-resume reproduces the
uninterrupted run bit-for-bit (deterministic data + jitted step)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticImages
from repro.models.cnn import CNN, CNNConfig
from repro.optim import AdamW
from repro.train.loop import SimulatedPreemption, Trainer, TrainConfig

CFG = CNNConfig(name="t", img_size=8, channels=(8, 8), pool_after=(0,))
DATA = SyntheticImages(img_size=8)


def _data_fn(step):
    return DATA.batch(step, 32)


def _mk(ckpt_dir, preempt_at=None, steps=24):
    model = CNN(CFG)
    params = model.init(jax.random.PRNGKey(0))
    return Trainer(model, params, AdamW(lr=1e-3), _data_fn, str(ckpt_dir),
                   TrainConfig(total_steps=steps, ckpt_every=8, log_every=8),
                   preempt_at=preempt_at)


def test_preempt_resume_bitwise_identical(tmp_path):
    # uninterrupted reference
    ref = _mk(tmp_path / "ref").run()

    # preempted at step 13 (between checkpoints), then auto-resumed
    with pytest.raises(SimulatedPreemption):
        _mk(tmp_path / "pre", preempt_at=13).run()
    resumed_trainer = _mk(tmp_path / "pre")          # fresh process simulacrum
    assert resumed_trainer.start_step == 8           # newest complete ckpt
    out = resumed_trainer.run()

    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_skips_completed_work(tmp_path):
    t1 = _mk(tmp_path / "c", steps=16)
    t1.run()
    t2 = _mk(tmp_path / "c", steps=16)
    assert t2.start_step == 16
    out = t2.run()                                   # no-op resume
    for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_watchdog_records(tmp_path, monkeypatch):
    tr = _mk(tmp_path / "s", steps=12)
    import time as _time
    real_time = _time.time
    calls = {"n": 0}

    def fake_time():
        calls["n"] += 1
        return real_time()

    tr.run()
    assert isinstance(tr.straggler_events, list)     # mechanism exists & ran
    assert len(tr.step_times) == 12
