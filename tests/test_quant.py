"""Linear quantizer unit + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.quant import (fake_quant, fake_quant_per_channel, quant_pack_int8,
                         ste_fake_quant)
from repro.quant.linear_quant import dequant_int8

RNG = np.random.default_rng(42)


def _w(shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


def test_zero_bits_prunes():
    w = _w((32, 16))
    assert bool(jnp.all(fake_quant(w, 0, axis=1) == 0))


def test_full_bits_identity():
    w = _w((32, 16))
    assert bool(jnp.allclose(fake_quant(w, 32, axis=1), w))


def test_error_monotone_in_bits():
    # NOTE: starts at 2 -- symmetric signed quant has identical grids at
    # 1 and 2 bits (both have a single positive level).
    w = _w((64, 32))
    errs = [float(jnp.mean((w - fake_quant(w, b, axis=1)) ** 2))
            for b in (2, 4, 8, 12)]
    assert all(a > b for a, b in zip(errs, errs[1:]))


def test_per_channel_vector_bits():
    w = _w((64, 32))
    bits = np.asarray(RNG.integers(0, 9, size=32))
    q = fake_quant_per_channel(w, jnp.asarray(bits), axis=1)
    assert q.shape == w.shape
    assert bool(jnp.all(q[:, bits == 0] == 0))
    # channels at high bits are closer than at low bits on average
    if (bits >= 6).any() and ((bits >= 1) & (bits <= 2)).any():
        e_hi = float(jnp.mean((w - q)[:, bits >= 6] ** 2))
        e_lo = float(jnp.mean((w - q)[:, (bits >= 1) & (bits <= 2)] ** 2))
        assert e_hi < e_lo


@settings(max_examples=20, deadline=None)
@given(bits=st.integers(1, 12), rows=st.integers(1, 20),
       cols=st.integers(1, 20), seed=st.integers(0, 2**31 - 1))
def test_fake_quant_idempotent(bits, rows, cols, seed):
    """Quantizing a quantized tensor at the same bits is a fixed point."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32))
    q1 = fake_quant(w, bits, axis=1)
    q2 = fake_quant(q1, bits, axis=1)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(bits=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_quant_error_bound(bits, seed):
    """|x - Q(x)| <= scale/2 = amax / (2(2^(b-1)-1)) per channel."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(40, 8)).astype(np.float32))
    q = fake_quant(w, bits, axis=1)
    amax = jnp.max(jnp.abs(w), axis=0)
    levels = max(2 ** (bits - 1) - 1, 1)
    bound = amax / levels / 2 + 1e-6
    assert bool(jnp.all(jnp.abs(w - q) <= bound[None, :] + 1e-7))


def test_pack_int8_consistent_with_fake_quant():
    w = _w((32, 16))
    bits = jnp.asarray(RNG.integers(0, 9, size=16))
    qi, s, _ = quant_pack_int8(w, bits, axis=1)
    assert qi.dtype == jnp.int8
    dq = dequant_int8(qi, s)
    fq = fake_quant(w, jnp.clip(bits, 0, 8), axis=1)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(fq), atol=1e-6)


def test_ste_gradient_is_identity():
    import jax
    w = _w((8, 8))
    g = jax.grad(lambda x: jnp.sum(ste_fake_quant(x, jnp.float32(4.0), 1) ** 2)
                 )(w)
    # straight-through: d/dx sum(Q(x)^2) approx 2*Q(x) under STE
    np.testing.assert_allclose(np.asarray(g),
                               2 * np.asarray(fake_quant(w, 4, axis=1)),
                               rtol=1e-5, atol=1e-6)
