"""DDPG / hierarchical agent / Algorithm-1 bounder tests."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bound import LayerBounder
from repro.core.ddpg import ACTION_SCALE, DDPG, DDPGConfig, ReplayBuffer
from repro.quant.policy import LayerInfo, QuantizableGraph


def _graph(n_layers=4, macs=1000.0):
    layers = [LayerInfo(name=f"l{i}", kind="linear", c_in=8, c_out=8, k=1,
                        stride=1, macs=macs, numel=64,
                        param_path=(f"l{i}",), channel_axis=1, n_groups=4)
              for i in range(n_layers)]
    return QuantizableGraph(layers=layers)


def test_replay_buffer_ring():
    buf = ReplayBuffer(3, 1, size=5)
    for i in range(8):
        buf.push(np.full(3, i), [i], i, np.full(3, i + 1), False)
    assert len(buf) == 5
    batch = buf.sample(np.random.default_rng(0), 4)
    assert batch["s"].shape == (4, 3)
    assert set(np.unique(batch["r"])) <= {3., 4., 5., 6., 7.}


def test_ddpg_actions_in_range():
    agent = DDPG(DDPGConfig(state_dim=4, action_dim=2), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    for noise in (0.0, 0.5, 2.0):
        a = agent.act(np.zeros(4, np.float32), noise, rng)
        assert a.shape == (2,)
        assert (a >= 0).all() and (a <= ACTION_SCALE).all()


def test_ddpg_learns_simple_qtarget():
    """Critic loss decreases on a stationary synthetic problem."""
    agent = DDPG(DDPGConfig(state_dim=3, action_dim=1, gamma=0.0),
                 jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    s = rng.normal(size=(64, 3)).astype(np.float32)
    a = rng.uniform(0, 32, size=(64, 1)).astype(np.float32)
    r = -(a[:, 0] - 16.0) ** 2 / 64.0      # optimum at a=16
    batch = {"s": s, "a": a, "r": r, "s2": s,
             "done": np.ones(64, np.float32)}
    first = agent.update(batch)["critic_loss"]
    for _ in range(200):
        last = agent.update(batch)["critic_loss"]
    assert last < first * 0.5
    act = agent.act(s[0], 0.0, rng)[0]
    assert 8.0 < act < 24.0               # pulled toward the optimum


def test_layer_bounder_enforces_budget():
    g = _graph(4)
    b = LayerBounder(g, avg_bits_w=4.0, avg_bits_a=4.0, g_min=1.0)
    total_logic = sum(l.macs for l in g.layers)
    budget = total_logic * (4 / 32) * (4 / 32)
    # greedy HLC asking for max bits every layer must still fit the budget
    spent = 0.0
    for t, layer in enumerate(g.layers):
        gw, ga = b.bound_pair(t, 32.0, 32.0)
        spent += (gw / 32) * (ga / 32) * layer.macs
    assert spent <= budget * 1.05 + 1e-6


@settings(max_examples=20, deadline=None)
@given(target=st.floats(2.0, 16.0), asks=st.lists(
    st.tuples(st.floats(0, 32), st.floats(0, 32)), min_size=4, max_size=4))
def test_layer_bounder_budget_property(target, asks):
    g = _graph(4)
    b = LayerBounder(g, avg_bits_w=target, avg_bits_a=target, g_min=1.0)
    spent = 0.0
    for t, (gw_ask, ga_ask) in enumerate(asks):
        gw, ga = b.bound_pair(t, gw_ask, ga_ask)
        assert 1.0 <= gw <= 32.0 and 1.0 <= ga <= 32.0
        spent += (gw / 32) * (ga / 32) * g.layers[t].macs
    budget = sum(l.macs for l in g.layers) * (target / 32) ** 2
    # min-goal floor may exceed tiny budgets; allow the g_min floor term
    floor = sum(l.macs for l in g.layers) * (1 / 32) ** 2
    assert spent <= max(budget, floor) * 1.2 + 1e-6


def test_var_ordering_projection():
    from repro.core.env import QuantEnv
    import jax.numpy as jnp
    from repro.core.reward import RewardCfg

    g = _graph(1)
    params = {"l0": jnp.asarray(
        np.random.default_rng(0).normal(size=(8, 8)) *
        np.asarray([0.1, 0.1, 1, 1, 2, 2, 4, 4]))}
    env = QuantEnv(g, params, lambda p: 50.0, RewardCfg.accuracy_guaranteed())
    actions = np.array([7.0, 1.0, 5.0, 3.0])
    out = env.apply_var_ordering(g.layers[0], actions)
    var = env.group_vars["l0"]
    order = np.argsort(var)
    assert sorted(out.tolist()) == sorted(actions.tolist())  # same multiset
    assert all(out[order][i] <= out[order][i + 1] for i in range(3))
