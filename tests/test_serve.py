"""Serving engine: greedy decode correctness + quantized-policy serving."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import LM
from repro.quant.policy import QuantPolicy
from repro.serve import ServeEngine

KEY = jax.random.PRNGKey(0)


def _greedy_ref(model, params, tokens, n_new):
    """Reference: re-run the full forward per generated token."""
    toks = jnp.asarray(tokens)
    for _ in range(n_new):
        logits, _ = model.apply(params, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        toks = jnp.concatenate([toks, nxt], axis=1)
    return np.asarray(toks[:, tokens.shape[1]:])


def test_engine_matches_full_forward_greedy():
    cfg = ARCHS["internlm2-20b"].smoke
    model = LM(cfg)
    params = model.init(KEY)
    tokens = np.asarray(jax.random.randint(KEY, (2, 6), 0, cfg.vocab))
    eng = ServeEngine(model, params, max_len=32)
    out = eng.generate(tokens, n_new=5)
    ref = _greedy_ref(model, params, tokens, 5)
    np.testing.assert_array_equal(out["tokens"], ref)
    assert out["stats"].tokens_out == 10


def test_engine_sliding_window_prompt_longer_than_window():
    """Prompt > window: the ring cache must evict oldest-first during
    decode.  Regression for the prefill ring misalignment that dropped a
    still-in-window position on the first decode overwrite (caught by the
    paged-engine parity tests)."""
    cfg = ARCHS["gemma2-2b"].smoke
    assert cfg.window is not None
    model = LM(cfg)
    params = model.init(KEY)
    tokens = np.asarray(
        jax.random.randint(KEY, (2, cfg.window + 3), 0, cfg.vocab))
    eng = ServeEngine(model, params, max_len=32)
    out = eng.generate(tokens, n_new=4)
    ref = _greedy_ref(model, params, tokens, 4)
    np.testing.assert_array_equal(out["tokens"], ref)


def test_engine_with_quant_policy_runs():
    cfg = ARCHS["gemma2-2b"].smoke
    model = LM(cfg)
    params = model.init(KEY)
    graph = model.graph(seq_len=8, batch=2)
    policy = QuantPolicy.uniform(graph, 8.0)
    eng = ServeEngine(model, params, policy=policy, graph=graph, max_len=32)
    tokens = np.asarray(jax.random.randint(KEY, (2, 6), 0, cfg.vocab))
    out = eng.generate(tokens, n_new=4)
    assert out["tokens"].shape == (2, 4)
    assert (out["tokens"] >= 0).all() and (out["tokens"] < cfg.vocab).all()


def test_weight_hbm_bytes_across_all_three_stores():
    """weight_hbm_bytes() accounting for raw / fake-quant / packed stores.

    raw and fake stores are all-dense f32 (fake-quant keeps full-size
    tensors by design -- search-time numerics, no byte savings); the packed
    store moves the searched weights into PackedWeight buffers and must
    report a strictly smaller total on a sub-8-bit policy."""
    cfg = ARCHS["gemma2-2b"].smoke
    model = LM(cfg)
    params = model.init(KEY)
    graph = model.graph(seq_len=4, batch=2)
    policy = QuantPolicy.uniform(graph, 4.0)

    raw = ServeEngine(model, params, max_len=16).weight_hbm_bytes()
    fake = ServeEngine(model, params, policy=policy, graph=graph,
                       max_len=16).weight_hbm_bytes()
    packed = ServeEngine(model, params, policy=policy, graph=graph,
                         max_len=16,
                         weight_store="packed").weight_hbm_bytes()

    param_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree_util.tree_leaves(params))
    for store in (raw, fake, packed):
        assert store["total"] == (store["packed"] + store["int8"]
                                  + store["dense"])
    # raw: every leaf is dense, byte count is exactly the param pytree's
    assert raw == {"packed": 0, "int8": 0, "dense": param_bytes,
                   "total": param_bytes}
    # fake: quantized values, full-precision storage
    assert fake["packed"] == 0 and fake["int8"] == 0
    assert fake["total"] == raw["total"]
    # packed: searched weights leave the dense bucket into packed storage
    assert packed["packed"] > 0
    assert packed["dense"] < raw["dense"]
    assert packed["total"] < 0.5 * raw["total"]    # 4-bit policy vs f32


def test_quantized_engine_degrades_gracefully():
    """8-bit serving should mostly agree with fp serving; 1-bit should not."""
    cfg = ARCHS["internlm2-20b"].smoke
    model = LM(cfg)
    params = model.init(KEY)
    graph = model.graph(seq_len=8, batch=2)
    tokens = np.asarray(jax.random.randint(KEY, (2, 6), 0, cfg.vocab))
    full = ServeEngine(model, params, max_len=24).generate(tokens, 4)
    q8 = ServeEngine(model, params, policy=QuantPolicy.uniform(graph, 8.0),
                     graph=graph, max_len=24).generate(tokens, 4)
    agree8 = (full["tokens"] == q8["tokens"]).mean()
    assert agree8 >= 0.5
