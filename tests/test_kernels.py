"""Pallas kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(3)

SHAPES_MM = [(128, 128, 128), (256, 384, 512), (100, 200, 300), (64, 130, 70),
             (1, 128, 128), (130, 128, 257)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES_MM)
@pytest.mark.parametrize("dtype", DTYPES)
def test_quant_matmul_allclose(shape, dtype):
    M, K, N = shape
    x = jnp.asarray(RNG.normal(size=(M, K)), dtype)
    qw = jnp.asarray(RNG.integers(-127, 128, size=(K, N)), jnp.int8)
    s = jnp.asarray(RNG.uniform(0.01, 0.1, size=(N,)), jnp.float32)
    y = ops.quant_matmul(x, qw, s)
    yr = ref.quant_matmul_ref(x, qw, s)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape,planes", [((128, 128, 128), 1),
                                          ((64, 100, 70), 4),
                                          ((256, 130, 128), 8)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_binary_matmul_allclose(shape, planes, dtype):
    M, K, N = shape
    x = jnp.asarray(RNG.normal(size=(M, K)), dtype)
    B = jnp.asarray(RNG.choice([-1, 1], size=(planes, K, N)), jnp.int8)
    a = jnp.asarray(RNG.uniform(0.1, 1.0, size=(planes, N)), jnp.float32)
    y = ops.binary_matmul(x, B, a)
    yr = ref.binary_matmul_ref(x, B, a)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape", [(256, 128), (100, 70), (512, 257)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fake_quant_kernel_allclose(shape, dtype):
    M, N = shape
    x = jnp.asarray(RNG.normal(size=(M, N)), dtype)
    bits = jnp.asarray(RNG.integers(0, 9, size=(N,)), jnp.float32)
    lv = jnp.maximum(2.0 ** (bits - 1) - 1, 1.0)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=0)
    sc = jnp.where(amax > 0, amax / lv, 1.0)
    y = ops.fake_quant_channels(x, sc, lv, bits)
    yr = ref.fake_quant_ref(x, sc, lv, bits)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=1e-2, atol=1e-2)


def test_quant_matmul_equals_full_dequant_matmul():
    """Kernel output == x @ dequantized weights (the semantic contract)."""
    from repro.quant import quant_pack_int8
    x = jnp.asarray(RNG.normal(size=(64, 96)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(96, 48)), jnp.float32)
    bits = jnp.asarray(RNG.integers(2, 9, size=48))
    qw, s, _ = quant_pack_int8(w, bits, axis=1)
    y = ops.quant_matmul(x, qw, s.reshape(-1))
    wq = qw.astype(jnp.float32) * s
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ wq),
                               rtol=1e-4, atol=1e-4)


def test_block_shape_sweep():
    x = jnp.asarray(RNG.normal(size=(256, 256)), jnp.float32)
    qw = jnp.asarray(RNG.integers(-127, 128, size=(256, 256)), jnp.int8)
    s = jnp.asarray(RNG.uniform(0.01, 0.1, size=(256,)), jnp.float32)
    yr = ref.quant_matmul_ref(x, qw, s)
    for bm, bn, bk in [(128, 128, 128), (256, 128, 64), (64, 256, 256)]:
        y = ops.quant_matmul(x, qw, s, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-3, atol=1e-2)
