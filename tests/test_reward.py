"""NetScore / reward-protocol tests."""
import numpy as np

from repro.core.reward import RewardCfg, extrinsic_reward, netscore
from repro.core.roofline import TPURoofline
from repro.quant.policy import (LayerInfo, QuantPolicy, QuantizableGraph,
                                QuantMode)


def _graph():
    return QuantizableGraph(layers=[
        LayerInfo(name="l0", kind="linear", c_in=8, c_out=8, k=1, stride=1,
                  macs=1e6, numel=64, param_path=("l0",), channel_axis=1,
                  n_groups=8)])


def test_netscore_monotone_in_accuracy():
    cfg = RewardCfg.accuracy_guaranteed()
    assert netscore(90, 0.2, 0.1, cfg) > netscore(80, 0.2, 0.1, cfg)


def test_netscore_rewards_compression_in_ag_mode():
    cfg = RewardCfg.accuracy_guaranteed()
    assert netscore(90, 0.1, 0.05, cfg) > netscore(90, 0.2, 0.1, cfg)


def test_rc_mode_ignores_cost():
    cfg = RewardCfg.resource_constrained()
    assert np.isclose(netscore(90, 0.1, 0.05, cfg),
                      netscore(90, 0.9, 0.9, cfg))


def test_flop_reward_ignores_weight_term():
    g = _graph()
    p_small_w = QuantPolicy.uniform(g, 2.0)
    p_big_w = QuantPolicy.uniform(g, 2.0)
    p_big_w.weight_bits["l0"][:] = 16.0   # heavier weights, same act bits
    cfg = RewardCfg.flop_based()
    r1 = extrinsic_reward(80.0, g, p_small_w, cfg)
    r2 = extrinsic_reward(80.0, g, p_big_w, cfg)
    # FLOP reward still sees logic ops (w*a), but not the p(N) weight-size
    # term: manually compare against netscore with p forced to 1
    from repro.core.reward import netscore as ns
    m1 = p_small_w.logic_ops(g) / (g.total_macs * 32 * 32)
    assert np.isclose(r1, ns(80.0, 1.0, m1, cfg))


def test_roofline_latency_monotone_in_bits():
    g = _graph()
    rl = TPURoofline()
    lat = [rl.latency(g, QuantPolicy.uniform(g, b)) for b in (2, 4, 8, 16)]
    assert lat[0] <= lat[1] <= lat[2] <= lat[3]
    assert rl.energy(g, QuantPolicy.uniform(g, 2)) < \
        rl.energy(g, QuantPolicy.uniform(g, 16))


def test_storage_overhead_below_paper_bound():
    """Paper section 3.4: 6-bit QBN storage per channel is < 0.3% overhead."""
    g = _graph()
    policy = QuantPolicy.uniform(g, 8.0)
    qbn_storage_bits = 6 * sum(l.c_out for l in g.layers)
    model_bits = policy.model_size_bits(g)
    assert qbn_storage_bits / model_bits < 0.3
