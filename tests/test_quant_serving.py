"""int8 serving-weight transform + int8 KV cache correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import LM

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch_id", ["internlm2-20b", "jamba-1.5-large-398b",
                                     "mamba2-780m"])
def test_int8_weights_track_fp(arch_id):
    cfg = ARCHS[arch_id].smoke
    model = LM(cfg)
    params = model.init(KEY)
    qparams = model.quantize_params_int8(params)
    # every matmul leaf became {"q": int8, "s": f32}; norms stayed fp
    flat = jax.tree_util.tree_flatten_with_path(qparams)[0]
    n_q = sum(1 for p, l in flat if str(p[-1]).endswith("'q'") or
              (hasattr(p[-1], "key") and p[-1].key == "q"))
    assert n_q > 0
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab)
    lf, _ = model.apply(params, {"tokens": toks})
    lq, _ = model.apply(qparams, {"tokens": toks})
    # int8 per-channel weights: logits stay close in relative terms
    denom = jnp.maximum(jnp.std(lf.astype(jnp.float32)), 1e-6)
    rel = float(jnp.mean(jnp.abs(lf - lq)) / denom)
    assert rel < 0.35, rel


def test_int8_kv_cache_matches_fp_closely():
    cfg = ARCHS["internlm2-20b"].smoke
    model = LM(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    c8 = model.init_cache(2, 16, dtype=jnp.float32, kv_bits=8)
    cf = model.init_cache(2, 16, dtype=jnp.float32)
    assert c8[0]["k"].dtype == jnp.int8 and "k_s" in c8[0]
    l8, c8 = model.prefill(params, {"tokens": toks[:, :8]}, c8)
    lf, cf = model.prefill(params, {"tokens": toks[:, :8]}, cf)
    np.testing.assert_allclose(np.asarray(l8), np.asarray(lf), atol=0.05)
    for t in range(8, 12):
        l8, c8 = model.decode_step(params, toks[:, t:t + 1], c8, jnp.int32(t))
        lf, cf = model.decode_step(params, toks[:, t:t + 1], cf, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(l8), np.asarray(lf), atol=0.2)


def test_moe_local_dispatch_no_mesh_is_identity():
    """local_dispatch without an active mesh falls back to the exact path."""
    import dataclasses as dc
    base = ARCHS["granite-moe-3b-a800m"].smoke
    cfg = dc.replace(base, moe=dc.replace(base.moe, local_dispatch=True))
    m1, m2 = LM(base), LM(cfg)
    params = m1.init(KEY)
    toks = jax.random.randint(KEY, (2, 8), 0, base.vocab)
    l1, _ = m1.apply(params, {"tokens": toks})
    l2, _ = m2.apply(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_ep_pad_preserves_routing_semantics():
    """Padded (never-routed) experts must not change outputs."""
    import dataclasses as dc
    base = ARCHS["llama4-scout-17b-a16e"].smoke
    model = LM(base)
    params = model.init(KEY)
    padded_cfg = dc.replace(base, moe=dc.replace(base.moe, pad_to=8))
    pm = LM(padded_cfg)
    pparams = pm.init(KEY)
    # copy the real experts into the padded tensors
    def graft(src, dst):
        out = jax.tree_util.tree_map(lambda a: a, dst)
        for i, blk in enumerate(src["blocks"]):
            for k in ("wg", "wu", "wd"):
                if k in blk:
                    tgt = out["blocks"][i][k]
                    out["blocks"][i][k] = tgt.at[:, :blk[k].shape[1]].set(
                        blk[k])
        for k in ("embed", "unembed", "final_norm"):
            out[k] = src[k]
        # copy attention + norms + router
        for i, blk in enumerate(src["blocks"]):
            for k, v in blk.items():
                if k not in ("wg", "wu", "wd"):
                    out["blocks"][i][k] = v
        return out

    pparams = graft(params, pparams)
    toks = jax.random.randint(KEY, (2, 8), 0, base.vocab)
    l1, _ = model.apply(params, {"tokens": toks})
    l2, _ = pm.apply(pparams, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)
