"""Training substrate: loop, checkpointing, fault tolerance, QAT."""
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import Trainer, TrainConfig

__all__ = ["CheckpointManager", "Trainer", "TrainConfig"]
