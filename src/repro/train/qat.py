"""Quantization-aware fine-tuning of the best-explored policy (paper: "After
the network quantization and binarization policy search is done, the
best-explored model is fine-tuned to obtain the best inference accuracy").

Weights pass through the straight-through fake quantizer at the policy's
per-channel bit-widths every forward; activations quantize at the policy's
per-layer bits.  Gradients flow to the latent full-precision weights.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.optim import AdamW
from repro.quant.linear_quant import ste_fake_quant
from repro.quant.policy import QuantPolicy, QuantizableGraph


from repro.quant.apply import _get_path, _set_path  # shared helpers


def make_qat_loss(model, graph: QuantizableGraph, policy: QuantPolicy,
                  base_loss_kwargs: Dict | None = None) -> Callable:
    wbits = {l.name: jnp.asarray(policy.expand_weight_bits(l))
             for l in graph.layers}
    act_ctx = {l.name: jnp.float32(policy.act_bits[l.name])
               for l in graph.layers}
    kw = base_loss_kwargs or {}

    def loss(params, batch):
        qp = params
        for layer in graph.layers:
            w = _get_path(params, layer.param_path)
            qw = ste_fake_quant(w, wbits[layer.name], layer.channel_axis)
            qp = _set_path(qp, layer.param_path, qw)
        return model.loss(qp, batch, act_bits=act_ctx, **kw)

    return loss


def qat_finetune(model, params, graph, policy, data_fn, steps: int = 50,
                 lr: float = 3e-4):
    """Returns fine-tuned params (latent fp weights)."""
    loss_fn = make_qat_loss(model, graph, policy)
    opt = AdamW(lr=lr, grad_clip=1.0)
    state = opt.init(params)

    @jax.jit
    def step_fn(params, state, batch):
        l, g = jax.value_and_grad(loss_fn)(params, batch)
        params, state, _ = opt.update(params, g, state)
        return params, state, l

    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data_fn(i).items()}
        params, state, l = step_fn(params, state, batch)
    return params
