"""Fault-tolerant checkpointing in pure JAX/numpy (no orbax offline).

Design (DESIGN.md section 5):
* **atomic**: write to ``<dir>/tmp.<step>`` then ``os.replace`` -- a killed
  writer never corrupts the latest checkpoint;
* **logical layout**: leaves stored by tree-path name, so restore maps onto a
  *template* pytree (from eval_shape) and can re-shard onto a different mesh
  than the one that saved -- the elastic-scaling path;
* **bf16-safe**: numpy cannot serialize bfloat16; leaves are stored as raw
  bit patterns with the dtype recorded in the manifest;
* **keep-k** garbage collection + auto-resume from the newest complete step.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        tmp = self.dir / f"tmp.{step}"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": int(step), "leaves": {}, "extra": extra or {}}
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        arrays = {}
        for i, (path, leaf) in enumerate(flat):
            name = _path_str(path)
            arr = np.asarray(jax.device_get(leaf))
            dt = str(arr.dtype)
            if dt in _BITCAST:
                arr = arr.view(_BITCAST[dt])
            key = f"a{i}"
            arrays[key] = arr
            manifest["leaves"][name] = {"key": key, "dtype": dt,
                                        "shape": list(arr.shape)}
        np.savez(tmp / "data.npz", **arrays)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore onto the structure of `like` (template pytree).

        shardings: optional matching pytree of NamedSharding -- restoring
        onto a different mesh than the saver's is supported (elastic).
        Returns (step, tree, extra).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "data.npz")

        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                      if shardings is not None else [None] * len(flat))
        leaves = []
        for (path, leaf), sh in zip(flat, shard_flat):
            name = _path_str(path)
            if name not in manifest["leaves"]:
                raise KeyError(f"checkpoint missing leaf {name}")
            meta = manifest["leaves"][name]
            arr = data[meta["key"]]
            if meta["dtype"] in _BITCAST:
                arr = arr.view(jnp.dtype(meta["dtype"]))
            want = tuple(leaf.shape)
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"shape mismatch for {name}: ckpt {arr.shape} vs {want}")
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jnp.asarray(arr))
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves)
        return step, tree, manifest.get("extra", {})
