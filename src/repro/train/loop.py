"""Training loop with checkpoint/restart, deterministic data skip-ahead,
and a straggler watchdog.

Fault model (1000+ node posture, DESIGN.md section 5): any step may die
(preemption, node loss).  Recovery = restart the job; the Trainer auto-resumes
from the newest complete checkpoint and replays the data stream from the
restored step (the synthetic pipeline is deterministic in (seed, index), so no
data-state checkpointing is needed).  A watchdog records per-step wall time
and flags outliers (> straggler_factor x median) -- on real clusters this
signal feeds eviction + elastic restart, which `restore(shardings=...)`
supports by re-sharding onto the new mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.optim import AdamW
from repro.train.checkpoint import CheckpointManager


class SimulatedPreemption(RuntimeError):
    pass


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    lr: float = 1e-3
    keep: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


class Trainer:
    """model: exposes .loss(params, batch); data_fn(step)->batch."""

    def __init__(self, model, params, optimizer: AdamW,
                 data_fn: Callable[[int], Dict[str, Any]],
                 ckpt_dir: str, cfg: TrainConfig = TrainConfig(),
                 loss_kwargs: Optional[dict] = None,
                 preempt_at: Optional[int] = None):
        self.model = model
        self.optimizer = optimizer
        self.data_fn = data_fn
        self.cfg = cfg
        self.ckpt = CheckpointManager(ckpt_dir, keep=cfg.keep)
        self.preempt_at = preempt_at
        self.history: List[Dict[str, float]] = []
        self.step_times: List[float] = []
        self.straggler_events: List[int] = []
        lk = loss_kwargs or {}

        def _step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, **lk))(params)
            params, opt_state, om = optimizer.update(params, grads, opt_state,
                                                     lr=cfg.lr)
            return params, opt_state, {"loss": loss, **om}

        self._step = jax.jit(_step)

        # resume or fresh start
        self.params = params
        self.opt_state = optimizer.init(params)
        self.start_step = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            s, tree, _ = self.ckpt.restore(
                {"params": self.params, "opt": self.opt_state}, step=latest)
            self.params, self.opt_state = tree["params"], tree["opt"]
            self.start_step = s
            print(f"[trainer] resumed from step {s}", flush=True)

    def run(self) -> Dict[str, Any]:
        cfg = self.cfg
        step = self.start_step
        while step < cfg.total_steps:
            if self.preempt_at is not None and step == self.preempt_at:
                raise SimulatedPreemption(f"preempted at step {step}")
            t0 = time.time()
            batch = self.data_fn(step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, m = self._step(
                self.params, self.opt_state, batch)
            dt = time.time() - t0
            self.step_times.append(dt)
            med = float(np.median(self.step_times[-50:]))
            if len(self.step_times) > 5 and dt > cfg.straggler_factor * med:
                self.straggler_events.append(step)
            step += 1
            if step % cfg.log_every == 0 or step == cfg.total_steps:
                self.history.append(
                    {"step": step, **{k: float(v) for k, v in m.items()}})
            if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
                self.ckpt.save(step, {"params": self.params,
                                      "opt": self.opt_state})
        return {"params": self.params, "opt": self.opt_state,
                "history": self.history,
                "stragglers": self.straggler_events}
