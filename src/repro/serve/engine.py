"""Batched serving engine: prefill a prompt batch, decode with a KV cache.

AutoQ integration: the engine deploys a searched :class:`QuantPolicy` --
weights are quantized once at load (fake-quant numerics; the packed-int8 HBM
layout and the fused dequant Pallas kernel are benchmarked separately in
kernels/), activations at the policy's per-block bits during decode.

This is the jnp-everywhere path: it runs on a laptop CPU and under a
production mesh unchanged (the dry-run lowers the same prefill/decode steps
against the 256/512-chip meshes).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import LM
from repro.quant.apply import apply_policy_to_params
from repro.quant.policy import QuantPolicy


@dataclasses.dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0

    @property
    def decode_tok_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class ServeEngine:
    def __init__(self, model: LM, params, policy: Optional[QuantPolicy] = None,
                 graph=None, max_len: int = 512, cache_dtype=jnp.float32):
        self.model = model
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        if policy is not None:
            graph = graph or model.graph(seq_len=1, batch=1)
            params = apply_policy_to_params(params, graph, policy)
        self.params = params
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def generate(self, tokens: np.ndarray, n_new: int,
                 temperature: float = 0.0, seed: int = 0
                 ) -> Dict[str, Any]:
        """tokens: (B, S_prompt) int32.  Greedy (T=0) or sampled decode."""
        B, S = tokens.shape
        assert S + n_new <= self.max_len
        cache = self.model.init_cache(B, self.max_len, dtype=self.cache_dtype)
        stats = ServeStats()
        t0 = time.time()
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(tokens)}, cache)
        logits.block_until_ready()
        stats.prefill_s = time.time() - t0

        rng = jax.random.PRNGKey(seed)
        out = []
        t0 = time.time()
        cur = None
        for i in range(n_new):
            if temperature > 0:
                rng, k = jax.random.split(rng)
                cur = jax.random.categorical(
                    k, logits[:, -1].astype(jnp.float32) / temperature, -1)
            else:
                cur = jnp.argmax(logits[:, -1], -1)
            cur = cur.astype(jnp.int32)[:, None]
            out.append(np.asarray(cur))
            logits, cache = self._decode(self.params, cur, cache,
                                         jnp.int32(S + i))
        jax.block_until_ready(logits)
        stats.decode_s = time.time() - t0
        stats.tokens_out = B * n_new
        return {"tokens": np.concatenate(out, axis=1), "stats": stats}
