"""Batched serving engine: prefill a prompt batch, decode with a KV cache.

AutoQ integration: the engine deploys a searched :class:`QuantPolicy` at
weight-load time, with per-layer dispatch between two weight stores:

* ``weight_store="fake"`` -- fake-quantized f32 tensors (search-time
  numerics, full-size HBM footprint);
* ``weight_store="packed"`` -- the bucketed sub-byte layout
  (quant.apply.apply_policy_packed): channels with QBN <= 4 bit-packed
  along K (kernels/pack.py), 5..8 int8, > 8 bf16, so stored bytes track the
  searched policy.  ``models.layers.deq`` unpacks at use; on TPU the unpack
  fuses into the consuming matmul (kernels/packed_matmul.py is the
  explicit-tiling version, benchmarked in benchmarks/packed_vs_int8.py).

Activations are NOT yet quantized in the serve path (the policy's per-block
activation QBNs are a ROADMAP open item; quant.apply.quantize_activation
exists but the engine does not thread it into prefill/decode).  This is
the jnp-everywhere path: it runs on a laptop CPU and under a production mesh
unchanged (the dry-run lowers the same prefill/decode steps against the
256/512-chip meshes).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.pack import PackedWeight
from repro.models.transformer import LM
from repro.quant.apply import apply_policy_packed, apply_policy_to_params
from repro.quant.policy import QuantPolicy


@dataclasses.dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0

    @property
    def decode_tok_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class ServeEngine:
    def __init__(self, model: LM, params, policy: Optional[QuantPolicy] = None,
                 graph=None, max_len: int = 512, cache_dtype=jnp.float32,
                 weight_store: str = "fake"):
        if weight_store not in ("fake", "packed"):
            raise ValueError(f"unknown weight_store {weight_store!r}")
        if weight_store == "packed" and policy is None:
            raise ValueError("weight_store='packed' requires a policy "
                             "(without one the engine would silently serve "
                             "dense full-precision weights)")
        self.model = model
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.weight_store = weight_store
        if policy is not None:
            graph = graph or model.graph(seq_len=1, batch=1)
            if weight_store == "packed":
                params = apply_policy_packed(params, graph, policy)
            else:
                params = apply_policy_to_params(params, graph, policy)
        self.params = params
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def weight_hbm_bytes(self) -> Dict[str, int]:
        """Stored weight bytes by leaf kind.

        ``packed`` counts PackedWeight buffers + scales (the sub-byte
        store); ``int8`` counts {"q","s"} leaves; ``dense`` everything else.
        The packed total is what a searched 4-bit-average policy's HBM
        weight traffic actually costs -- the quantity core/roofline.py's
        reward models."""
        out = {"packed": 0, "int8": 0, "dense": 0}
        leaves = jax.tree_util.tree_leaves_with_path(
            self.params, is_leaf=lambda x: isinstance(x, PackedWeight))
        for path, leaf in leaves:
            if isinstance(leaf, PackedWeight):
                out["packed"] += leaf.hbm_bytes()
            elif any(getattr(p, "key", None) in ("q", "s") for p in path):
                out["int8"] += leaf.size * leaf.dtype.itemsize
            else:
                out["dense"] += leaf.size * leaf.dtype.itemsize
        out["total"] = out["packed"] + out["int8"] + out["dense"]
        return out

    def generate(self, tokens: np.ndarray, n_new: int,
                 temperature: float = 0.0, seed: int = 0
                 ) -> Dict[str, Any]:
        """tokens: (B, S_prompt) int32.  Greedy (T=0) or sampled decode."""
        B, S = tokens.shape
        assert S + n_new <= self.max_len
        cache = self.model.init_cache(B, self.max_len, dtype=self.cache_dtype)
        stats = ServeStats()
        t0 = time.time()
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(tokens)}, cache)
        logits.block_until_ready()
        stats.prefill_s = time.time() - t0

        rng = jax.random.PRNGKey(seed)
        out = []
        t0 = time.time()
        cur = None
        for i in range(n_new):
            if temperature > 0:
                rng, k = jax.random.split(rng)
                cur = jax.random.categorical(
                    k, logits[:, -1].astype(jnp.float32) / temperature, -1)
            else:
                cur = jnp.argmax(logits[:, -1], -1)
            cur = cur.astype(jnp.int32)[:, None]
            out.append(np.asarray(cur))
            logits, cache = self._decode(self.params, cur, cache,
                                         jnp.int32(S + i))
        jax.block_until_ready(logits)
        stats.decode_s = time.time() - t0
        stats.tokens_out = B * n_new
        return {"tokens": np.concatenate(out, axis=1), "stats": stats}
