"""Serving engine: single-batch prefill/decode plus continuous batching.

Two execution models share one weight store and one model:

* :meth:`ServeEngine.generate` -- the original batch-at-a-time path: one
  dense ``[B, max_len]`` KV cache, every sequence prefilled together, the
  whole batch decoded in lockstep.  It is the *oracle*: the paged path must
  reproduce its token streams per request.
* :meth:`ServeEngine.run` -- continuous batching over a paged KV cache
  with a unified token-budget step loop (``prefill="chunked"``, default):
  requests are admitted as soon as their *first prompt chunk* fits
  (serve/scheduler.py), and one jit'd ``model_step`` per iteration
  advances every in-flight sequence -- each contributing up to
  ``chunk_tokens`` prompt-chunk tokens or 1 decode token, K/V written
  straight into block-table pages (serve/paged_kv.py).  jit variants are
  bounded per (max_slots, chunk, pool shape), independent of prompt
  lengths.  ``prefill="monolithic"`` keeps the legacy
  prefill-then-decode state machine (batch-1 prefill scattered into the
  pool + ``decode_step_paged``): the only mode for hybrid mamba /
  cross-attention patterns, and the chunked mode's TTFT baseline.
  ``run(speculative=True)`` adds multi-token decode on top of the chunked
  loop: a draft pass proposes ``draft_k`` tokens per decoding lane, one
  verify ``model_step`` scores each lane's whole span as a chunk past its
  current position, and over-speculated KV pages roll back the same step
  -- emitted streams stay bit-identical for any draft
  (docs/speculative.md).

AutoQ integration: the engine deploys a searched :class:`QuantPolicy` at
weight-load time, with per-layer dispatch between two weight stores:

* ``weight_store="fake"`` -- fake-quantized f32 tensors (search-time
  numerics, full-size HBM footprint);
* ``weight_store="packed"`` -- the bucketed sub-byte layout
  (quant.apply.apply_policy_packed): channels with QBN <= 4 bit-packed
  along K (kernels/pack.py), 5..8 int8, > 8 bf16, so stored bytes track the
  searched policy.  ``models.layers.deq`` unpacks at use; on TPU the unpack
  fuses into the consuming matmul (kernels/packed_matmul.py is the
  explicit-tiling version, benchmarked in benchmarks/packed_vs_int8.py).

Both stores serve through *both* execution models unchanged -- the store is
a property of the parameters, not of the cache layout (invariant guarded by
tests/test_paged_kv.py parity tests).

Attention runs on the Pallas kernels by default (``attn_impl="pallas"``:
kernels/attention.py -- fused flash prefill + block-table paged decode, in
interpret mode off-TPU); ``attn_impl="ref"`` is the escape hatch back to
the jnp oracle path, which is also what the train/dry-run paths use.

Activation quantization: a policy's per-block activation QBNs are threaded
into prefill and decode (``serve_act_bits``, on by default), closing the
search->serve gap for activations the same way the weight stores close it
for weights.  ``kv_bits=8`` extends the int8 KV cache to the paged pool
(scale page per KV page; the Pallas decode kernel dequantizes in VMEM).
Everything still runs on a laptop CPU and under a production mesh unchanged
(the dry-run lowers the same prefill/decode steps against the 256/512-chip
meshes).
"""
from __future__ import annotations

import collections
import functools
import time
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.pack import PackedWeight
from repro.models.transformer import LM
from repro.quant.apply import apply_policy_packed, apply_policy_to_params
from repro.quant.policy import QuantPolicy
from repro.serve import paged_kv
from repro.serve.frontend import FrontEnd, as_request
from repro.serve.scheduler import Request, Scheduler
from repro.serve.stats import ServeStats          # re-export (home moved)
from repro.serve.step_loop import StepLoop

__all__ = ["ServeEngine", "ServeStats"]


class ServeEngine:
    def __init__(self, model: LM, params, policy: Optional[QuantPolicy] = None,
                 graph=None, max_len: int = 512, cache_dtype=jnp.float32,
                 weight_store: str = "fake", attn_impl: str = "pallas",
                 kv_bits: Optional[int] = None, serve_act_bits: bool = True):
        """attn_impl: attention backend for every engine model call
        (``"pallas"`` default / ``"ref"`` oracle escape hatch).  kv_bits=8
        stores the KV cache -- dense and paged alike -- as int8 with
        per-(position, head) scales.  serve_act_bits: thread the policy's
        per-block activation QBNs into prefill/decode (no-op without a
        policy)."""
        if weight_store not in ("fake", "packed"):
            raise ValueError(f"unknown weight_store {weight_store!r}")
        if weight_store == "packed" and policy is None:
            raise ValueError("weight_store='packed' requires a policy "
                             "(without one the engine would silently serve "
                             "dense full-precision weights)")
        from repro.models.layers import ATTN_IMPLS
        if attn_impl not in ATTN_IMPLS:
            raise ValueError(f"unknown attn_impl {attn_impl!r}; "
                             f"expected one of {ATTN_IMPLS}")
        if kv_bits not in (None, 8):
            raise ValueError(f"unsupported kv_bits {kv_bits!r}: only 8 "
                             "(int8 + per-(position, head) scales) is "
                             "implemented; None serves full-precision KV")
        self.model = model
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.weight_store = weight_store
        self.attn_impl = attn_impl
        self.kv_bits = kv_bits
        self.act_bits = None
        if policy is not None:
            graph = graph or model.graph(seq_len=1, batch=1)
            if weight_store == "packed":
                params = apply_policy_packed(params, graph, policy)
            else:
                params = apply_policy_to_params(params, graph, policy)
            if serve_act_bits:
                # the same policy -> per-block collapse the evaluator uses,
                # so serving quantizes activations exactly like search-time
                # evaluation (block scalar = input projection site's QBN)
                from repro.quant.linear_quant import FULL_BITS
                self.act_bits = model.block_act_bits(
                    graph, [policy.act_bits.get(l.name, float(FULL_BITS))
                            for l in graph.layers])
        self.params = params
        # trace counters: each jit *trace* (i.e. each compiled variant) runs
        # the python wrapper once, cache hits never do -- so these count
        # compiled variants per entry point.  The chunked step loop is
        # designed to keep trace_counts["model_step"] independent of the
        # number of distinct prompt lengths (regression-tested).
        self.trace_counts: Dict[str, int] = collections.Counter()

        def counted(name, fn):
            @functools.wraps(fn)
            def wrapped(*a, **kw):
                self.trace_counts[name] += 1
                return fn(*a, **kw)
            return wrapped

        self._prefill = jax.jit(counted("prefill", model.prefill),
                                static_argnames=("attn_impl",))
        self._decode = jax.jit(counted("decode_step", model.decode_step),
                               static_argnames=("attn_impl",))
        self._decode_paged = jax.jit(
            counted("decode_step_paged", model.decode_step_paged),
            static_argnames=("attn_impl",))
        self._model_step = jax.jit(counted("model_step", model.model_step),
                                   static_argnames=("attn_impl",))
        # the speculative draft pass runs the same unified step under its
        # own trace counter, so variant boundedness is auditable per role
        self._draft_step = jax.jit(counted("draft_step", model.model_step),
                                   static_argnames=("attn_impl",))

        def sample_span(logits, keys, temps):
            """Batched on-device sampling: every lane's candidate token(s)
            plus the rng key state per acceptance length, one device call.

            logits (R, C, V); keys (R, 2) raw uint32; temps (R,).  Returns
            ``toks`` (R, C) int32 and ``keys_seq`` (R, C+1, 2) where
            ``keys_seq[r, m]`` is lane r's key after consuming *m* tokens
            -- the caller gathers the state matching how many tokens each
            lane actually emitted, so rejected speculative columns never
            consume rng.  Bit-identical to the historical eager per-lane
            path: greedy lanes argmax (key untouched), sampled lanes
            split-then-categorical per emitted token, matching a
            single-request generate(seed) stream split-for-split.
            """
            def lane(lg, key, temp):
                safe = jnp.where(temp > 0, temp, jnp.float32(1.0))

                def col(key, row):
                    nk, k = jax.random.split(key)
                    samp = jax.random.categorical(
                        k, row.astype(jnp.float32) / safe, -1)
                    tok = jnp.where(temp > 0, samp,
                                    jnp.argmax(row, -1)).astype(jnp.int32)
                    nxt = jnp.where(temp > 0, nk, key)
                    return nxt, (tok, nxt)

                _, (toks, ks) = jax.lax.scan(col, key, lg)
                return toks, jnp.concatenate([key[None], ks], 0)

            return jax.vmap(lane)(logits, keys, temps)

        self._sample_span = jax.jit(counted("sample_step", sample_span))

        def draft_tail(params, cache, tables, slot_map, tok0, pos0, spans,
                       steps, act):
            """Fused draft proposal tail: the autoregressive (R, 1) chain
            ``d_2 .. d_k`` as one scanned jit instead of k-1 separate
            dispatches.  ``spans`` masks each lane (a lane proposes while
            its verify span still has columns: ``spans >= m + 2`` at tail
            iteration m); masked lanes carry sentinel positions, so their
            writes land in the trash page.  Returns the (k-1, R) proposal
            stack and the advanced draft cache."""
            zeros = jnp.zeros(tok0.shape, jnp.int32)

            def body(carry, mm):
                cache, tok = carry
                active = spans >= mm + 2
                pos = jnp.where(active, pos0 + mm, paged_kv.POS_SENTINEL)
                logits, cache = model.model_step(
                    params, tok[:, None], pos[:, None], slot_map, cache,
                    tables, zeros, act, attn_impl=self.attn_impl)
                prop = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                tok = jnp.where(active, prop, tok)
                return (cache, tok), prop

            (cache, _), props = jax.lax.scan(body, (cache, tok0), steps)
            return props, cache

        self._draft_tail = jax.jit(counted("draft_tail", draft_tail))

    def weight_hbm_bytes(self) -> Dict[str, int]:
        """Stored weight bytes by leaf kind.

        ``packed`` counts PackedWeight buffers + scales (the sub-byte
        store); ``int8`` counts {"q","s"} leaves; ``dense`` everything else.
        The packed total is what a searched 4-bit-average policy's HBM
        weight traffic actually costs -- the quantity core/roofline.py's
        reward models."""
        out = {"packed": 0, "int8": 0, "dense": 0}
        leaves = jax.tree_util.tree_leaves_with_path(
            self.params, is_leaf=lambda x: isinstance(x, PackedWeight))
        for path, leaf in leaves:
            if isinstance(leaf, PackedWeight):
                out["packed"] += leaf.hbm_bytes()
            elif any(getattr(p, "key", None) in ("q", "s") for p in path):
                out["int8"] += leaf.size * leaf.dtype.itemsize
            else:
                out["dense"] += leaf.size * leaf.dtype.itemsize
        out["total"] = out["packed"] + out["int8"] + out["dense"]
        return out

    # --------------------------------------------------------- single batch
    def generate(self, tokens: np.ndarray, n_new: int,
                 temperature: float = 0.0, seed: int = 0
                 ) -> Dict[str, Any]:
        """tokens: (B, S_prompt) int32.  Greedy (T=0) or sampled decode."""
        B, S = tokens.shape
        assert S + n_new <= self.max_len
        cache = self.model.init_cache(B, self.max_len, dtype=self.cache_dtype,
                                      kv_bits=self.kv_bits)
        stats = ServeStats(n_requests=B)
        t0 = time.time()
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(tokens)}, cache,
                                      self.act_bits,
                                      attn_impl=self.attn_impl)
        logits.block_until_ready()
        stats.prefill_s = time.time() - t0

        rng = jax.random.PRNGKey(seed)
        out = []
        t0 = time.time()
        cur = None
        for i in range(n_new):
            if temperature > 0:
                rng, k = jax.random.split(rng)
                cur = jax.random.categorical(
                    k, logits[:, -1].astype(jnp.float32) / temperature, -1)
            else:
                cur = jnp.argmax(logits[:, -1], -1)
            cur = cur.astype(jnp.int32)[:, None]
            out.append(np.asarray(cur))
            logits, cache = self._decode(self.params, cur, cache,
                                         jnp.int32(S + i), self.act_bits,
                                         attn_impl=self.attn_impl)
        jax.block_until_ready(logits)
        stats.decode_s = time.time() - t0
        stats.tokens_out = B * n_new
        stats.steps = n_new
        return {"tokens": np.concatenate(out, axis=1), "stats": stats}

    # --------------------------------------------------- continuous batching
    def run(self, requests: Sequence[Union[Request, Dict[str, Any], tuple]],
            *, page_size: int = 16, max_slots: int = 8,
            num_pages: Optional[int] = None, prefill: Optional[str] = None,
            chunk_tokens: Optional[int] = None,
            token_budget: Optional[int] = None, speculative: bool = False,
            draft_k: int = 4, draft_policy: str = "prefix",
            draft_layers: Optional[int] = None,
            draft_act_bits: Optional[float] = None,
            overlap: bool = True) -> Dict[str, Any]:
        """Serve a workload of mixed-length requests with continuous batching.

        Since the open-loop split (docs/serving.md), ``run()`` is a thin
        *closed-loop client* of the open-loop core: it submits every
        request to a :class:`~repro.serve.frontend.FrontEnd` up front
        (all arriving "now") and drains :meth:`serve` -- the degenerate
        arrival pattern.  ``overlap`` (chunked mode) selects the
        pipelined back-end that dispatches step t+1 before syncing step
        t's tokens; ``overlap=False`` forces synchronous stepping.  Both
        produce bit-identical streams -- the parity suite runs the
        matrix.

        requests: each a :class:`Request`, a ``{"tokens", "n_new",
        "temperature"?, "seed"?}`` dict, or a ``(tokens, n_new)`` tuple;
        ``tokens`` is a 1-D prompt.  Per-request greedy/sampled decode
        follows the same rng discipline as a single-request
        :meth:`generate` call with that request's seed, so greedy outputs
        are comparable token-for-token against independent ``generate``
        calls -- under *either* prefill mode:

        * ``prefill="chunked"`` (default where supported): the unified
          token-budget step loop.  Prefill and decode are one jit'd
          ``model_step`` per iteration; each in-flight sequence contributes
          up to ``chunk_tokens`` prompt-chunk tokens or 1 decode token,
          bounded by ``token_budget`` real tokens per step, and prompt K/V
          is written straight into block-table pages (no batch-1 dense
          prefill, no per-prompt-length jit variants).  A request is
          admitted as soon as its *first chunk* fits.  Requires every cache
          kind to be ``"paged"`` (pure attention patterns).
        * ``prefill="monolithic"``: the legacy state machine -- one batch-1
          full-prompt prefill per admitted request scattered into the pool,
          then batched single-token decode steps.  The only mode for hybrid
          (mamba / cross-attention) patterns, whose recurrent state cannot
          chunk; kept as the TTFT baseline for the chunked path
          (benchmarks/continuous_batching.py).

        ``prefill=None`` auto-selects chunked where supported.
        chunk_tokens defaults to ``page_size``; token_budget to
        ``max_slots + chunk_tokens - 1`` (every decode lane plus one full
        chunk; with ``speculative=True``, ``max_slots * (draft_k + 1) +
        chunk_tokens - 1`` so full verify spans fit) and must be >=
        max_slots so decode lanes are never starved.

        ``speculative=True`` turns on multi-token decode
        (docs/speculative.md): each step a *draft* proposes up to
        ``draft_k`` tokens per decoding lane, one jit'd verify
        ``model_step`` runs every lane's ``[feedback, draft_1..draft_k]``
        span as a chunk past its current position (the same q-tile path
        chunked prefill uses), and the sampler keeps the longest
        draft/sample agreement prefix plus the corrected token --
        over-speculated KV pages roll back the same step.  Acceptance
        changes *throughput only*: token streams are bit-identical to a
        non-speculative ``run()`` for any draft, greedy and sampled alike
        (each emitted token comes from the same logits + rng split plain
        decode would use).  ``draft_policy="prefix"`` self-drafts with the
        first ``draft_layers`` (default ``n_repeat // 2``) repeats of this
        very model; ``"lowbit"`` re-runs the full model as the AutoQ-native
        cheap proxy -- ``draft_act_bits`` activation QBNs (default 4.0)
        and an int8-KV draft cache.  Each knob belongs to one policy and
        is rejected with the other: ``draft_layers`` is ``"prefix"``-only,
        ``draft_act_bits`` is ``"lowbit"``-only.
        Requires chunked prefill: hybrid (mamba / cross-attn) patterns
        raise, like forcing ``prefill="chunked"`` does -- serve them
        non-speculatively through ``prefill="monolithic"``.

        page_size: KV positions per page.  max_slots: decode-batch width
        (compiled shape).  num_pages: pool size; default sizes for the
        worst case (``max_slots`` sequences at ``max_len``), which can never
        stall.  A smaller pool throttles admission (a request is admitted
        when its prompt -- chunked: first chunk -- plus one page of decode
        headroom fits); in chunked mode a sequence that cannot grow
        mid-*prefill* is preempted and requeued (it has emitted nothing, so
        its restarted stream is unchanged), and prefilling sequences are
        preempted to keep *decode* lanes growing.  Only when nothing is
        left to preempt -- the pool cannot back the running set's decode
        growth, or a lone request can never fit -- does
        :class:`~.paged_kv.PagesExhausted` propagate (in monolithic mode it
        still propagates on any mid-run growth failure, as before).  For
        all-sliding-window patterns, pages that fall wholly out of every
        future attention window are reclaimed at each step boundary, so
        pool occupancy is O(window) per sequence, not O(generated length).

        Returns ``{"outputs": [np.ndarray per request, submit order],
        "stats": ServeStats}`` (with per-request TTFT in ``stats``).
        """
        reqs = [as_request(i, r) for i, r in enumerate(requests)]
        for r in reqs:
            if r.prompt_len + r.n_new > self.max_len:
                raise ValueError(
                    f"request {r.rid}: {r.prompt_len}+{r.n_new} tokens "
                    f"exceeds max_len={self.max_len}")
        kinds = self.model.cfg.cache_kinds()
        chunkable = all(kd == "paged" for kd in kinds)
        if prefill is None:
            prefill = "chunked" if chunkable else "monolithic"
        if prefill not in ("chunked", "monolithic"):
            raise ValueError(f"unknown prefill mode {prefill!r}")
        if prefill == "chunked" and not chunkable:
            raise ValueError(
                f"prefill='chunked' needs all-paged cache kinds, got "
                f"{kinds}: recurrent/memory blocks cannot chunk -- use "
                "prefill='monolithic'")
        if speculative:
            # fail fast, before any model call: running the verify chunk
            # against recurrent state would silently corrupt it
            if not chunkable:
                raise ValueError(
                    f"speculative=True needs all-paged cache kinds, got "
                    f"{kinds}: recurrent/memory blocks cannot run the "
                    "multi-token verify chunk -- serve hybrid patterns "
                    "non-speculatively through prefill='monolithic'")
            if prefill == "monolithic":
                raise ValueError(
                    "speculative=True runs through the chunked model_step "
                    "loop; prefill='monolithic' cannot carry verify spans "
                    "-- drop speculative=True or use prefill='chunked'")
            self._validate_draft_args(draft_k, draft_policy, draft_layers,
                                      draft_act_bits)
        if prefill == "chunked":
            fe = FrontEnd()
            for r in reqs:
                fe.submit(r)
            res = self.serve(fe, page_size=page_size, max_slots=max_slots,
                             num_pages=num_pages, chunk_tokens=chunk_tokens,
                             token_budget=token_budget,
                             speculative=speculative, draft_k=draft_k,
                             draft_policy=draft_policy,
                             draft_layers=draft_layers,
                             draft_act_bits=draft_act_bits, overlap=overlap)
            return {"outputs": [res["outputs"][r.rid] for r in reqs],
                    "stats": res["stats"]}
        blocks_per_seq = paged_kv.pages_needed(self.max_len, page_size)
        if num_pages is None:
            num_pages = max_slots * blocks_per_seq + 1      # +1: trash page
        cache = self.model.init_paged_cache(max_slots, num_pages, page_size,
                                            dtype=self.cache_dtype,
                                            kv_bits=self.kv_bits)
        sched = Scheduler(max_slots, page_size,
                          blocks_per_seq, paged_kv.PageAllocator(num_pages))
        for r in reqs:
            sched.submit(r)
        outputs: Dict[int, List[int]] = {r.rid: [] for r in reqs}
        stats = ServeStats(n_requests=len(reqs), mode=prefill)
        self._run_monolithic(reqs, sched, cache, kinds, outputs, stats,
                             num_pages, page_size,
                             self._reclaim_window(kinds))
        return {"outputs": [np.asarray(outputs[r.rid], np.int32)
                            for r in reqs],
                "stats": stats}

    def serve(self, frontend: FrontEnd, *, page_size: int = 16,
              max_slots: int = 8, num_pages: Optional[int] = None,
              chunk_tokens: Optional[int] = None,
              token_budget: Optional[int] = None, speculative: bool = False,
              draft_k: int = 4, draft_policy: str = "prefix",
              draft_layers: Optional[int] = None,
              draft_act_bits: Optional[float] = None,
              overlap: bool = True) -> Dict[str, Any]:
        """Open-loop serving: drain a :class:`FrontEnd` of timestamped
        arrivals through the overlapped step loop.

        The open-loop core of the serving split (docs/serving.md):
        requests may arrive *while the loop runs* -- pre-scheduled with
        ``frontend.submit(..., at=t)`` (the Poisson bench), or live from
        another thread.  Each iteration pumps due arrivals into the
        scheduler (shedding SLO-overdue waiters), admits what fits, and
        runs one token-budget ``model_step``; with ``overlap=True``
        (default, non-speculative) the host plans and dispatches step
        t+1 before syncing step t's sampled tokens, so the device never
        waits on host sampling (serve/step_loop.py documents the
        pipeline and its exact-feedback invariant).  The loop returns
        when every scheduled arrival has been served or shed -- a
        closed-*loop* client like :meth:`run` simply submits everything
        up front.

        Chunked-only: the open-loop core requires all-paged cache kinds
        (hybrid mamba / cross-attention patterns serve through
        ``run(prefill="monolithic")``).  ``speculative=True`` rides the
        same back-end synchronously (acceptance control flow needs token
        values); the remaining knobs match :meth:`run`.

        Returns ``{"outputs": {rid: np.ndarray}, "stats": ServeStats,
        "shed": [rid, ...]}`` -- shed requests (reported in both
        ``shed`` and ``stats.shed``) have empty output streams.
        """
        kinds = self.model.cfg.cache_kinds()
        if not all(kd == "paged" for kd in kinds):
            raise ValueError(
                f"open-loop serving needs all-paged cache kinds, got "
                f"{kinds}: recurrent/memory blocks cannot chunk -- serve "
                "hybrid patterns through run(prefill='monolithic')")
        if speculative:
            self._validate_draft_args(draft_k, draft_policy, draft_layers,
                                      draft_act_bits)
        chunk = chunk_tokens if chunk_tokens is not None else page_size
        if token_budget is not None:
            budget = token_budget
        elif speculative:
            # room for every lane's full verify span plus one chunk
            budget = max_slots * (draft_k + 1) + chunk - 1
        else:
            budget = max_slots + chunk - 1
        if chunk < 1:
            raise ValueError(f"chunk_tokens must be >= 1, got {chunk}")
        if budget < max_slots:
            raise ValueError(
                f"token_budget={budget} < max_slots={max_slots}: every "
                "decode lane needs a token each step (decode is never "
                "deferred); raise the budget or shrink the batch")
        blocks_per_seq = paged_kv.pages_needed(self.max_len, page_size)
        if num_pages is None:
            num_pages = max_slots * blocks_per_seq + 1      # +1: trash page
        cache = self.model.init_paged_cache(max_slots, num_pages, page_size,
                                            dtype=self.cache_dtype,
                                            kv_bits=self.kv_bits)
        sched = Scheduler(max_slots, page_size,
                          blocks_per_seq, paged_kv.PageAllocator(num_pages))
        spec = self._make_draft(
            max_slots, num_pages, page_size, draft_k, draft_policy,
            draft_layers, draft_act_bits) if speculative else None
        stats = ServeStats(mode="chunked",
                           overlapped=bool(overlap) and not speculative)
        loop = StepLoop(self, frontend, sched, cache, kinds, stats,
                        num_pages=num_pages, page_size=page_size,
                        chunk=chunk, budget=budget,
                        reclaim=self._reclaim_window(kinds), spec=spec,
                        overlap=overlap)
        loop.run()
        stats.n_requests = frontend.n_submitted
        stats.shed = list(frontend.shed)
        outputs = {rid: np.asarray(toks, np.int32)
                   for rid, toks in loop.outputs.items()}
        for rid in frontend.shed:
            outputs.setdefault(rid, np.zeros((0,), np.int32))
        return {"outputs": outputs, "stats": stats,
                "shed": list(frontend.shed)}

    def _reclaim_window(self, kinds) -> Optional[int]:
        # out-of-window reclamation is sound only when *every* block of the
        # pattern attends through the same sliding window (a single global
        # block needs the whole history; one block table serves all layers)
        cfg = self.model.cfg
        chunkable = all(kd == "paged" for kd in kinds)
        return cfg.window if (chunkable and cfg.window is not None and
                              all(b.kind == "local_attn"
                                  for b in cfg.pattern)) else None

    @staticmethod
    def _validate_draft_args(draft_k, draft_policy, draft_layers,
                             draft_act_bits) -> None:
        if draft_k < 1:
            raise ValueError(f"draft_k must be >= 1, got {draft_k}")
        if draft_policy not in ("prefix", "lowbit"):
            raise ValueError(f"unknown draft_policy {draft_policy!r}; "
                             "expected 'prefix' or 'lowbit'")
        if draft_layers is not None and draft_policy != "prefix":
            raise ValueError("draft_layers applies to "
                             "draft_policy='prefix' only")
        if draft_act_bits is not None and draft_policy != "lowbit":
            raise ValueError("draft_act_bits applies to "
                             "draft_policy='lowbit' only (the prefix "
                             "draft serves the target's own act QBNs)")

    # ------------------------------------------------- speculative drafting
    def _make_draft(self, max_slots, num_pages, page_size, draft_k,
                    draft_policy, draft_layers, draft_act_bits):
        """Build the draft pass state for one speculative ``run()``.

        The draft is *another view of the same engine*: it proposes tokens
        through the very ``model_step`` the target verifies with, against
        its own paged cache that shares the main stream's block tables
        (same positions, same page ids -- rollback and scrub cover both).

        * ``"prefix"``: the first ``draft_layers`` stacked repeats of the
          served params (``LM.draft_prefix_params``) -- no extra weights,
          cache stacked to the prefix depth.  ``draft_layers == n_repeat``
          makes the draft the target (acceptance 1.0, the bench ceiling).
        * ``"lowbit"``: the full model as its own cheap proxy, AutoQ
          style -- ``draft_act_bits`` activation QBNs everywhere and an
          int8-KV draft cache, so the draft pays low-bit compute/traffic
          for the same depth.
        """
        model, cfg = self.model, self.model.cfg
        if draft_policy == "prefix":
            d = draft_layers if draft_layers is not None \
                else max(1, cfg.n_repeat // 2)
            params = model.draft_prefix_params(self.params, d)
            act = None if self.act_bits is None else self.act_bits[:d]
            dcache = model.init_paged_cache(
                max_slots, num_pages, page_size, dtype=self.cache_dtype,
                kv_bits=self.kv_bits, n_repeat=d)
        else:                                     # "lowbit"
            params = self.params
            act = jnp.full((cfg.n_repeat, len(cfg.pattern)),
                           4.0 if draft_act_bits is None
                           else float(draft_act_bits), jnp.float32)
            dcache = model.init_paged_cache(
                max_slots, num_pages, page_size, dtype=self.cache_dtype,
                kv_bits=8)
        return {"params": params, "cache": dcache, "act": act, "k": draft_k,
                "frontier": {}}

    def _draft_propose(self, spec, plan, sched, spec_lanes, w1):
        """Run the draft pass for one step; returns slot -> draft tokens.

        Call 1 carries three kinds of rows: prompt-chunk rows keep the
        draft cache's prompt KV warm (without this, chunks fed while no
        lane was speculating would leave holes and crater acceptance);
        every decode row feeds its feedback token, *preceded by a one-token
        catch-up when the previous verify step accepted its whole span*
        (the last draft was proposed but never fed back, so the draft
        cache trails the stream by one position -- ``spec["frontier"]``
        tracks each lane's draft write cursor; after a rejection the
        frontier clamps back, because everything past the acceptance
        point is rejected-token KV that the stream overwrites in place);
        and each speculating row's last-real-column logits propose its
        first draft token.  The remaining proposals ``d_2 .. d_k`` are
        one *fused* ``draft_tail`` jit -- a scanned (R, 1) chain feeding
        each lane's previous proposal at the next position, exactly the
        autoregressive loop the verify step collapses, without the k-1
        per-call dispatch + transfer overhead the overlapped back-end
        would otherwise stall on (lanes whose span ends early are
        sentinel-masked; the whole proposal stack syncs as one
        transfer).  Draft proposals are greedy by design: the draft is a
        guess, the verify sampler is the ground truth.  ``w1`` is call
        1's width (the chunk width, or 2 on chunkless steps -- feedback
        plus the catch-up column), so the draft compiles two bounded
        ``draft_step`` shapes plus a single ``draft_tail`` shape."""
        n = plan["tokens"].shape[0]
        tables = jnp.asarray(sched.tables.as_array())
        slot_map = jnp.asarray(plan["slot_map"])
        frontier = spec.setdefault("frontier", {})
        dtok = np.zeros((n, w1), np.int32)
        dpos = np.full((n, w1), paged_kv.POS_SENTINEL, np.int32)
        lcols = np.zeros((n,), np.int32)
        for i, c in plan["chunked"].items():      # mirror prompt chunks
            dtok[i, :c] = plan["tokens"][i, :c]
            dpos[i, :c] = plan["positions"][i, :c]
            lcols[i] = c - 1
        for i in plan["spec"]:                    # decode rows (any span)
            s = sched.slot(i)
            catch = min(s.pos - frontier.get(i, s.pos), 1)
            if catch:                             # re-feed the accepted
                dtok[i, 0] = s.out[s.pos - 1 - s.req.prompt_len]
                dpos[i, 0] = s.pos - 1            # last draft of last span
            dtok[i, catch] = s.out[-1]
            dpos[i, catch] = s.pos
            lcols[i] = catch
        logits, spec["cache"] = self._draft_step(
            spec["params"], jnp.asarray(dtok), jnp.asarray(dpos), slot_map,
            spec["cache"], tables, jnp.asarray(lcols), spec["act"],
            attn_impl=self.attn_impl)
        prop = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        max_cols = max(spec_lanes.values(), default=1)
        if max_cols > 2:
            # fused tail: d_2..d_k for every lane in one scanned jit, one
            # proposal-stack transfer.  Always k-1 iterations (static scan
            # length keeps draft_tail at one compiled variant); lanes whose
            # span ends early run sentinel-masked into the trash page.
            spans = np.zeros((n,), np.int32)
            pos0 = np.zeros((n,), np.int32)
            for i, cols in spec_lanes.items():
                spans[i] = cols
                pos0[i] = sched.slot(i).pos
            props, spec["cache"] = self._draft_tail(
                spec["params"], spec["cache"], tables, slot_map, prop,
                jnp.asarray(pos0), jnp.asarray(spans),
                jnp.arange(1, spec["k"], dtype=jnp.int32), spec["act"])
            all_props = np.array(jnp.concatenate([prop[None], props], 0),
                                 np.int32)
        else:
            all_props = np.array(prop, np.int32)[None]
        # np.array (not asarray): callers own writable draft arrays
        drafts = {i: all_props[:cols - 1, i]
                  for i, cols in spec_lanes.items()}
        for i, cols in plan["spec"].items():      # draft write cursors
            frontier[i] = sched.slot(i).pos + max(cols - 1, 1)
        return drafts

    def _run_monolithic(self, reqs, sched, cache, kinds, outputs, stats,
                        num_pages, page_size, reclaim):
        """Legacy prefill-then-decode state machine (hybrid archs; TTFT
        baseline for the chunked loop).  Sampling runs through the same
        batched device sampler as the chunked back-end: one
        ``sample_step`` call and one (R,)-token transfer per decode step
        instead of a full logits pull plus per-lane host sampling."""
        t_run = time.time()
        n = sched.n_slots
        keys = jnp.zeros((n, 2), jnp.uint32)
        temps = jnp.zeros((n,), jnp.float32)
        while sched.has_work:
            # ---- admission: prefill queued requests into free slots/pages
            admitted = 0
            while (adm := sched.try_admit()) is not None:
                admitted += 1
                req, slot, pages = adm
                t0 = time.time()
                logits, dense = self._prefill_one(req, page_size)
                cache = paged_kv.scrub_pages(cache, kinds, pages)
                cache = paged_kv.write_prefill(cache, dense, kinds, slot,
                                               pages, page_size)
                keys = keys.at[slot].set(jax.random.PRNGKey(req.seed))
                temps = temps.at[slot].set(jnp.float32(req.temperature))
                toks, kseq = self._sample_span(logits[:, -1:],
                                               keys[slot:slot + 1],
                                               temps[slot:slot + 1])
                keys = keys.at[slot].set(kseq[0, 1])
                tok = int(np.asarray(toks)[0, 0])
                stats.prefill_s += time.time() - t0
                outputs[req.rid].append(tok)
                stats.tokens_out += 1
                stats.prefill_tokens += 1
                stats.mono_prefill_tokens += req.prompt_len
                stats.ttft_steps[req.rid] = stats.steps + 1
                stats.ttft_s[req.rid] = time.time() - t_run
                sched.bind(slot, req, tok)
            stats.peak_pages = max(stats.peak_pages,
                                   num_pages - 1 - sched.allocator.n_free)

            running = sched.running_slots()
            if not running:
                if sched.has_work and not admitted:
                    raise paged_kv.PagesExhausted(
                        "queued request cannot ever be admitted: pool of "
                        f"{num_pages} pages (page_size={page_size}) is too "
                        "small for its prompt + decode headroom")
                continue                    # everything admitted finished

            # ---- one batched decode step over all in-flight sequences
            # reclaim outside the timed section, like the chunked loop, so
            # decode_s compares like-for-like across modes
            if reclaim is not None:
                stats.reclaimed_pages += len(
                    sched.reclaim_out_of_window(reclaim))
            t0 = time.time()
            fresh = sched.ensure_pages()
            cache = paged_kv.scrub_pages(cache, kinds, fresh)
            b = sched.batch()
            logits, cache = self._decode_paged(
                self.params, jnp.asarray(b["tokens"]), cache,
                jnp.asarray(b["block_tables"]), jnp.asarray(b["pos"]),
                self.act_bits, attn_impl=self.attn_impl)
            toks, kseq = self._sample_span(logits[:, -1:], keys, temps)
            m = np.zeros((n,), np.int32)
            m[running] = 1                  # idle lanes never consume rng
            keys = kseq[jnp.arange(n), jnp.asarray(m)]
            vals = np.asarray(toks)         # one transfer for the batch
            for i in running:
                req = sched.slot(i).req
                tok = int(vals[i, 0])
                outputs[req.rid].append(tok)
                stats.tokens_out += 1
                sched.record(i, tok)
            stats.decode_s += time.time() - t0
            stats.steps += 1

    # ---------------------------------------------------------- run helpers
    def _prefill_one(self, req: Request, page_size: int):
        """Batch-1 prefill into a dense cache sized to whole pages.

        The cache length only pads the KV store (prefill logits are computed
        from the in-flight k/v, not read back), so rounding the prompt up to
        a page multiple bounds jit variants without changing numerics."""
        L = paged_kv.pages_needed(req.prompt_len, page_size) * page_size
        dense = self.model.init_cache(1, L, dtype=self.cache_dtype,
                                      kv_bits=self.kv_bits)
        logits, dense = self._prefill(
            self.params, {"tokens": jnp.asarray(req.tokens[None])}, dense,
            self.act_bits, attn_impl=self.attn_impl)
        return logits, dense
