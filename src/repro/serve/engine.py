"""Serving engine: single-batch prefill/decode plus continuous batching.

Two execution models share one weight store and one model:

* :meth:`ServeEngine.generate` -- the original batch-at-a-time path: one
  dense ``[B, max_len]`` KV cache, every sequence prefilled together, the
  whole batch decoded in lockstep.  It is the *oracle*: the paged path must
  reproduce its token streams per request.
* :meth:`ServeEngine.run` -- continuous batching over a paged KV cache
  with a unified token-budget step loop (``prefill="chunked"``, default):
  requests are admitted as soon as their *first prompt chunk* fits
  (serve/scheduler.py), and one jit'd ``model_step`` per iteration
  advances every in-flight sequence -- each contributing up to
  ``chunk_tokens`` prompt-chunk tokens or 1 decode token, K/V written
  straight into block-table pages (serve/paged_kv.py).  jit variants are
  bounded per (max_slots, chunk, pool shape), independent of prompt
  lengths.  ``prefill="monolithic"`` keeps the legacy
  prefill-then-decode state machine (batch-1 prefill scattered into the
  pool + ``decode_step_paged``): the only mode for hybrid mamba /
  cross-attention patterns, and the chunked mode's TTFT baseline.
  ``run(speculative=True)`` adds multi-token decode on top of the chunked
  loop: a draft pass proposes ``draft_k`` tokens per decoding lane, one
  verify ``model_step`` scores each lane's whole span as a chunk past its
  current position, and over-speculated KV pages roll back the same step
  -- emitted streams stay bit-identical for any draft
  (docs/speculative.md).

AutoQ integration: the engine deploys a searched :class:`QuantPolicy` at
weight-load time, with per-layer dispatch between two weight stores:

* ``weight_store="fake"`` -- fake-quantized f32 tensors (search-time
  numerics, full-size HBM footprint);
* ``weight_store="packed"`` -- the bucketed sub-byte layout
  (quant.apply.apply_policy_packed): channels with QBN <= 4 bit-packed
  along K (kernels/pack.py), 5..8 int8, > 8 bf16, so stored bytes track the
  searched policy.  ``models.layers.deq`` unpacks at use; on TPU the unpack
  fuses into the consuming matmul (kernels/packed_matmul.py is the
  explicit-tiling version, benchmarked in benchmarks/packed_vs_int8.py).

Both stores serve through *both* execution models unchanged -- the store is
a property of the parameters, not of the cache layout (invariant guarded by
tests/test_paged_kv.py parity tests).

Attention runs on the Pallas kernels by default (``attn_impl="pallas"``:
kernels/attention.py -- fused flash prefill + block-table paged decode, in
interpret mode off-TPU); ``attn_impl="ref"`` is the escape hatch back to
the jnp oracle path, which is also what the train/dry-run paths use.

Activation quantization: a policy's per-block activation QBNs are threaded
into prefill and decode (``serve_act_bits``, on by default), closing the
search->serve gap for activations the same way the weight stores close it
for weights.  ``kv_bits=8`` extends the int8 KV cache to the paged pool
(scale page per KV page; the Pallas decode kernel dequantizes in VMEM).
Everything still runs on a laptop CPU and under a production mesh unchanged
(the dry-run lowers the same prefill/decode steps against the 256/512-chip
meshes).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.pack import PackedWeight
from repro.models.transformer import LM
from repro.quant.apply import apply_policy_packed, apply_policy_to_params
from repro.quant.policy import QuantPolicy
from repro.serve import paged_kv
from repro.serve.scheduler import Request, Scheduler
from repro.serve.stats import ServeStats          # re-export (home moved)

__all__ = ["ServeEngine", "ServeStats"]


class ServeEngine:
    def __init__(self, model: LM, params, policy: Optional[QuantPolicy] = None,
                 graph=None, max_len: int = 512, cache_dtype=jnp.float32,
                 weight_store: str = "fake", attn_impl: str = "pallas",
                 kv_bits: Optional[int] = None, serve_act_bits: bool = True):
        """attn_impl: attention backend for every engine model call
        (``"pallas"`` default / ``"ref"`` oracle escape hatch).  kv_bits=8
        stores the KV cache -- dense and paged alike -- as int8 with
        per-(position, head) scales.  serve_act_bits: thread the policy's
        per-block activation QBNs into prefill/decode (no-op without a
        policy)."""
        if weight_store not in ("fake", "packed"):
            raise ValueError(f"unknown weight_store {weight_store!r}")
        if weight_store == "packed" and policy is None:
            raise ValueError("weight_store='packed' requires a policy "
                             "(without one the engine would silently serve "
                             "dense full-precision weights)")
        from repro.models.layers import ATTN_IMPLS
        if attn_impl not in ATTN_IMPLS:
            raise ValueError(f"unknown attn_impl {attn_impl!r}; "
                             f"expected one of {ATTN_IMPLS}")
        if kv_bits not in (None, 8):
            raise ValueError(f"unsupported kv_bits {kv_bits!r}: only 8 "
                             "(int8 + per-(position, head) scales) is "
                             "implemented; None serves full-precision KV")
        self.model = model
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.weight_store = weight_store
        self.attn_impl = attn_impl
        self.kv_bits = kv_bits
        self.act_bits = None
        if policy is not None:
            graph = graph or model.graph(seq_len=1, batch=1)
            if weight_store == "packed":
                params = apply_policy_packed(params, graph, policy)
            else:
                params = apply_policy_to_params(params, graph, policy)
            if serve_act_bits:
                # the same policy -> per-block collapse the evaluator uses,
                # so serving quantizes activations exactly like search-time
                # evaluation (block scalar = input projection site's QBN)
                from repro.quant.linear_quant import FULL_BITS
                self.act_bits = model.block_act_bits(
                    graph, [policy.act_bits.get(l.name, float(FULL_BITS))
                            for l in graph.layers])
        self.params = params
        # trace counters: each jit *trace* (i.e. each compiled variant) runs
        # the python wrapper once, cache hits never do -- so these count
        # compiled variants per entry point.  The chunked step loop is
        # designed to keep trace_counts["model_step"] independent of the
        # number of distinct prompt lengths (regression-tested).
        self.trace_counts: Dict[str, int] = collections.Counter()

        def counted(name, fn):
            @functools.wraps(fn)
            def wrapped(*a, **kw):
                self.trace_counts[name] += 1
                return fn(*a, **kw)
            return wrapped

        self._prefill = jax.jit(counted("prefill", model.prefill),
                                static_argnames=("attn_impl",))
        self._decode = jax.jit(counted("decode_step", model.decode_step),
                               static_argnames=("attn_impl",))
        self._decode_paged = jax.jit(
            counted("decode_step_paged", model.decode_step_paged),
            static_argnames=("attn_impl",))
        self._model_step = jax.jit(counted("model_step", model.model_step),
                                   static_argnames=("attn_impl",))
        # the speculative draft pass runs the same unified step under its
        # own trace counter, so variant boundedness is auditable per role
        self._draft_step = jax.jit(counted("draft_step", model.model_step),
                                   static_argnames=("attn_impl",))

    def weight_hbm_bytes(self) -> Dict[str, int]:
        """Stored weight bytes by leaf kind.

        ``packed`` counts PackedWeight buffers + scales (the sub-byte
        store); ``int8`` counts {"q","s"} leaves; ``dense`` everything else.
        The packed total is what a searched 4-bit-average policy's HBM
        weight traffic actually costs -- the quantity core/roofline.py's
        reward models."""
        out = {"packed": 0, "int8": 0, "dense": 0}
        leaves = jax.tree_util.tree_leaves_with_path(
            self.params, is_leaf=lambda x: isinstance(x, PackedWeight))
        for path, leaf in leaves:
            if isinstance(leaf, PackedWeight):
                out["packed"] += leaf.hbm_bytes()
            elif any(getattr(p, "key", None) in ("q", "s") for p in path):
                out["int8"] += leaf.size * leaf.dtype.itemsize
            else:
                out["dense"] += leaf.size * leaf.dtype.itemsize
        out["total"] = out["packed"] + out["int8"] + out["dense"]
        return out

    # --------------------------------------------------------- single batch
    def generate(self, tokens: np.ndarray, n_new: int,
                 temperature: float = 0.0, seed: int = 0
                 ) -> Dict[str, Any]:
        """tokens: (B, S_prompt) int32.  Greedy (T=0) or sampled decode."""
        B, S = tokens.shape
        assert S + n_new <= self.max_len
        cache = self.model.init_cache(B, self.max_len, dtype=self.cache_dtype,
                                      kv_bits=self.kv_bits)
        stats = ServeStats(n_requests=B)
        t0 = time.time()
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(tokens)}, cache,
                                      self.act_bits,
                                      attn_impl=self.attn_impl)
        logits.block_until_ready()
        stats.prefill_s = time.time() - t0

        rng = jax.random.PRNGKey(seed)
        out = []
        t0 = time.time()
        cur = None
        for i in range(n_new):
            if temperature > 0:
                rng, k = jax.random.split(rng)
                cur = jax.random.categorical(
                    k, logits[:, -1].astype(jnp.float32) / temperature, -1)
            else:
                cur = jnp.argmax(logits[:, -1], -1)
            cur = cur.astype(jnp.int32)[:, None]
            out.append(np.asarray(cur))
            logits, cache = self._decode(self.params, cur, cache,
                                         jnp.int32(S + i), self.act_bits,
                                         attn_impl=self.attn_impl)
        jax.block_until_ready(logits)
        stats.decode_s = time.time() - t0
        stats.tokens_out = B * n_new
        stats.steps = n_new
        return {"tokens": np.concatenate(out, axis=1), "stats": stats}

    # --------------------------------------------------- continuous batching
    def run(self, requests: Sequence[Union[Request, Dict[str, Any], tuple]],
            *, page_size: int = 16, max_slots: int = 8,
            num_pages: Optional[int] = None, prefill: Optional[str] = None,
            chunk_tokens: Optional[int] = None,
            token_budget: Optional[int] = None, speculative: bool = False,
            draft_k: int = 4, draft_policy: str = "prefix",
            draft_layers: Optional[int] = None,
            draft_act_bits: Optional[float] = None) -> Dict[str, Any]:
        """Serve a workload of mixed-length requests with continuous batching.

        requests: each a :class:`Request`, a ``{"tokens", "n_new",
        "temperature"?, "seed"?}`` dict, or a ``(tokens, n_new)`` tuple;
        ``tokens`` is a 1-D prompt.  Per-request greedy/sampled decode
        follows the same rng discipline as a single-request
        :meth:`generate` call with that request's seed, so greedy outputs
        are comparable token-for-token against independent ``generate``
        calls -- under *either* prefill mode:

        * ``prefill="chunked"`` (default where supported): the unified
          token-budget step loop.  Prefill and decode are one jit'd
          ``model_step`` per iteration; each in-flight sequence contributes
          up to ``chunk_tokens`` prompt-chunk tokens or 1 decode token,
          bounded by ``token_budget`` real tokens per step, and prompt K/V
          is written straight into block-table pages (no batch-1 dense
          prefill, no per-prompt-length jit variants).  A request is
          admitted as soon as its *first chunk* fits.  Requires every cache
          kind to be ``"paged"`` (pure attention patterns).
        * ``prefill="monolithic"``: the legacy state machine -- one batch-1
          full-prompt prefill per admitted request scattered into the pool,
          then batched single-token decode steps.  The only mode for hybrid
          (mamba / cross-attention) patterns, whose recurrent state cannot
          chunk; kept as the TTFT baseline for the chunked path
          (benchmarks/continuous_batching.py).

        ``prefill=None`` auto-selects chunked where supported.
        chunk_tokens defaults to ``page_size``; token_budget to
        ``max_slots + chunk_tokens - 1`` (every decode lane plus one full
        chunk; with ``speculative=True``, ``max_slots * (draft_k + 1) +
        chunk_tokens - 1`` so full verify spans fit) and must be >=
        max_slots so decode lanes are never starved.

        ``speculative=True`` turns on multi-token decode
        (docs/speculative.md): each step a *draft* proposes up to
        ``draft_k`` tokens per decoding lane, one jit'd verify
        ``model_step`` runs every lane's ``[feedback, draft_1..draft_k]``
        span as a chunk past its current position (the same q-tile path
        chunked prefill uses), and the sampler keeps the longest
        draft/sample agreement prefix plus the corrected token --
        over-speculated KV pages roll back the same step.  Acceptance
        changes *throughput only*: token streams are bit-identical to a
        non-speculative ``run()`` for any draft, greedy and sampled alike
        (each emitted token comes from the same logits + rng split plain
        decode would use).  ``draft_policy="prefix"`` self-drafts with the
        first ``draft_layers`` (default ``n_repeat // 2``) repeats of this
        very model; ``"lowbit"`` re-runs the full model as the AutoQ-native
        cheap proxy -- ``draft_act_bits`` activation QBNs (default 4.0)
        and an int8-KV draft cache.  Each knob belongs to one policy and
        is rejected with the other: ``draft_layers`` is ``"prefix"``-only,
        ``draft_act_bits`` is ``"lowbit"``-only.
        Requires chunked prefill: hybrid (mamba / cross-attn) patterns
        raise, like forcing ``prefill="chunked"`` does -- serve them
        non-speculatively through ``prefill="monolithic"``.

        page_size: KV positions per page.  max_slots: decode-batch width
        (compiled shape).  num_pages: pool size; default sizes for the
        worst case (``max_slots`` sequences at ``max_len``), which can never
        stall.  A smaller pool throttles admission (a request is admitted
        when its prompt -- chunked: first chunk -- plus one page of decode
        headroom fits); in chunked mode a sequence that cannot grow
        mid-*prefill* is preempted and requeued (it has emitted nothing, so
        its restarted stream is unchanged), and prefilling sequences are
        preempted to keep *decode* lanes growing.  Only when nothing is
        left to preempt -- the pool cannot back the running set's decode
        growth, or a lone request can never fit -- does
        :class:`~.paged_kv.PagesExhausted` propagate (in monolithic mode it
        still propagates on any mid-run growth failure, as before).  For
        all-sliding-window patterns, pages that fall wholly out of every
        future attention window are reclaimed at each step boundary, so
        pool occupancy is O(window) per sequence, not O(generated length).

        Returns ``{"outputs": [np.ndarray per request, submit order],
        "stats": ServeStats}`` (with per-request TTFT in ``stats``).
        """
        reqs = [self._as_request(i, r) for i, r in enumerate(requests)]
        for r in reqs:
            if r.prompt_len + r.n_new > self.max_len:
                raise ValueError(
                    f"request {r.rid}: {r.prompt_len}+{r.n_new} tokens "
                    f"exceeds max_len={self.max_len}")
        kinds = self.model.cfg.cache_kinds()
        chunkable = all(kd == "paged" for kd in kinds)
        if prefill is None:
            prefill = "chunked" if chunkable else "monolithic"
        if prefill not in ("chunked", "monolithic"):
            raise ValueError(f"unknown prefill mode {prefill!r}")
        if prefill == "chunked" and not chunkable:
            raise ValueError(
                f"prefill='chunked' needs all-paged cache kinds, got "
                f"{kinds}: recurrent/memory blocks cannot chunk -- use "
                "prefill='monolithic'")
        if speculative:
            # fail fast, before any model call: running the verify chunk
            # against recurrent state would silently corrupt it
            if not chunkable:
                raise ValueError(
                    f"speculative=True needs all-paged cache kinds, got "
                    f"{kinds}: recurrent/memory blocks cannot run the "
                    "multi-token verify chunk -- serve hybrid patterns "
                    "non-speculatively through prefill='monolithic'")
            if prefill == "monolithic":
                raise ValueError(
                    "speculative=True runs through the chunked model_step "
                    "loop; prefill='monolithic' cannot carry verify spans "
                    "-- drop speculative=True or use prefill='chunked'")
            if draft_k < 1:
                raise ValueError(f"draft_k must be >= 1, got {draft_k}")
            if draft_policy not in ("prefix", "lowbit"):
                raise ValueError(f"unknown draft_policy {draft_policy!r}; "
                                 "expected 'prefix' or 'lowbit'")
            if draft_layers is not None and draft_policy != "prefix":
                raise ValueError("draft_layers applies to "
                                 "draft_policy='prefix' only")
            if draft_act_bits is not None and draft_policy != "lowbit":
                raise ValueError("draft_act_bits applies to "
                                 "draft_policy='lowbit' only (the prefix "
                                 "draft serves the target's own act QBNs)")
        blocks_per_seq = paged_kv.pages_needed(self.max_len, page_size)
        if num_pages is None:
            num_pages = max_slots * blocks_per_seq + 1      # +1: trash page
        cache = self.model.init_paged_cache(max_slots, num_pages, page_size,
                                            dtype=self.cache_dtype,
                                            kv_bits=self.kv_bits)
        sched = Scheduler(max_slots, page_size,
                          blocks_per_seq, paged_kv.PageAllocator(num_pages))
        for r in reqs:
            sched.submit(r)
        outputs: Dict[int, List[int]] = {r.rid: [] for r in reqs}
        rngs: Dict[int, jax.Array] = {}
        stats = ServeStats(n_requests=len(reqs), mode=prefill)
        # out-of-window reclamation is sound only when *every* block of the
        # pattern attends through the same sliding window (a single global
        # block needs the whole history; one block table serves all layers)
        cfg = self.model.cfg
        reclaim = cfg.window if (chunkable and cfg.window is not None and
                                 all(b.kind == "local_attn"
                                     for b in cfg.pattern)) else None
        args = (reqs, sched, cache, kinds, outputs, rngs, stats, num_pages,
                page_size, reclaim)
        if prefill == "chunked":
            chunk = chunk_tokens if chunk_tokens is not None else page_size
            if token_budget is not None:
                budget = token_budget
            elif speculative:
                # room for every lane's full verify span plus one chunk
                budget = max_slots * (draft_k + 1) + chunk - 1
            else:
                budget = max_slots + chunk - 1
            if chunk < 1:
                raise ValueError(f"chunk_tokens must be >= 1, got {chunk}")
            if budget < max_slots:
                raise ValueError(
                    f"token_budget={budget} < max_slots={max_slots}: every "
                    "decode lane needs a token each step (decode is never "
                    "deferred); raise the budget or shrink the batch")
            spec = self._make_draft(
                max_slots, num_pages, page_size, draft_k, draft_policy,
                draft_layers, draft_act_bits) if speculative else None
            self._run_chunked(*args, chunk=chunk, budget=budget, spec=spec)
        else:
            self._run_monolithic(*args)
        return {"outputs": [np.asarray(outputs[r.rid], np.int32)
                            for r in reqs],
                "stats": stats}

    def _run_chunked(self, reqs, sched, cache, kinds, outputs, rngs, stats,
                     num_pages, page_size, reclaim, *, chunk, budget,
                     spec=None):
        """The unified token-budget step loop (prefill == decode).

        ``spec`` (from :meth:`_make_draft`) arms speculative multi-token
        decode: each step runs the draft pass (:meth:`_draft_propose`),
        one verify ``model_step`` over every lane's span, then the
        accept/rollback bookkeeping.  ``spec=None`` is the plain loop.
        """
        t_run = time.time()
        k = spec["k"] if spec else 0
        W = max(chunk, k + 1) if spec else chunk
        while sched.has_work:
            if reclaim is not None:
                stats.reclaimed_pages += len(
                    sched.reclaim_out_of_window(reclaim))
            # ---- admission: a request joins when its first chunk fits
            fresh = []
            while (adm := sched.try_admit_chunked(chunk)) is not None:
                fresh += adm[2]
            if not sched.running_slots():
                raise paged_kv.PagesExhausted(
                    "queued request cannot ever be admitted: pool of "
                    f"{num_pages} pages (page_size={page_size}) is too "
                    "small for its first chunk + decode headroom")
            t0 = time.time()
            plan = sched.plan_step(chunk, budget, draft_k=k)
            stats.requeues += len(plan["requeued"])
            # a request admitted above may have been preempted inside this
            # very plan_step: its admission pages are back on the free list
            # (possibly re-allocated -- then they are in plan["fresh"] under
            # the new owner), so drop the stale aliases from the scrub set
            drop = set(plan["freed"])
            fresh = [p for p in fresh if p not in drop]
            # scrub unconditionally: admission pages must be sentinel-clean
            # before any later step writes chunks into them, even if this
            # step is abandoned below.  The draft cache shares the block
            # tables, so it scrubs the same pages.
            cache = paged_kv.scrub_pages(cache, kinds, fresh + plan["fresh"])
            if spec:
                spec["cache"] = paged_kv.scrub_pages(
                    spec["cache"], kinds, fresh + plan["fresh"])
            if not plan["sample"] and not plan["chunked"]:
                continue            # every planned slot was preempted
            # pure-decode steps run the (R, 1) column slice -- a full-width
            # step would burn masked lanes per slot once every prompt is
            # in.  jit variants stay bounded per (max_slots, chunk, pool
            # shape[, draft_k]): mixed/verify width + pure-decode width,
            # still independent of prompt lengths.
            spec_lanes = {i: c for i, c in plan["spec"].items() if c > 1}
            w = W if (plan["chunked"] or spec_lanes) else 1
            tokens = plan["tokens"]
            if spec and (plan["chunked"] or plan["spec"]):
                # draft pass: mirrors prompt chunks into the draft cache,
                # feeds every decode lane's feedback token (even on steps
                # where page pressure degraded all spans to width 1 --
                # skipping those would leave draft-cache holes the 1-token
                # catch-up can never repair, permanently hurting
                # acceptance), and proposes each speculating lane's draft
                # tokens, which fill the placeholder verify columns
                drafts = self._draft_propose(spec, plan, sched, spec_lanes,
                                             W if plan["chunked"] else 2)
                for i, cols in spec_lanes.items():
                    tokens[i, 1:cols] = drafts[i][:cols - 1]
            logits, cache = self._model_step(
                self.params, jnp.asarray(tokens[:, :w]),
                jnp.asarray(plan["positions"][:, :w]),
                jnp.asarray(plan["slot_map"]), cache,
                jnp.asarray(sched.tables.as_array()),
                jnp.asarray(plan["logit_cols"]),
                self.act_bits, attn_impl=self.attn_impl)
            rows = np.asarray(logits)             # (R, C, V); C=1 plain
            stats.chunk_prefill_tokens += sum(plan["chunked"].values())
            emitted_step = 0
            for i in plan["sample"]:
                s = sched.slot(i)
                req = s.req
                if not s.out:                     # the request's first token
                    tok = self._next_token(req, rngs, rows[i, -1:])
                    outputs[req.rid].append(tok)
                    stats.tokens_out += 1
                    emitted_step += 1
                    stats.ttft_steps[req.rid] = stats.steps + 1
                    stats.ttft_s[req.rid] = time.time() - t_run
                    sched.record_first(i, tok)
                    continue
                # decode lane: walk the verify span, keeping the longest
                # draft/sample agreement prefix + the corrected token.
                # Every emitted token comes from the same logits row + rng
                # split plain decode would produce (rejected columns never
                # consume rng), so acceptance changes speed, never output.
                cols = plan["spec"].get(i, 1)
                emitted = []
                for j in range(cols):
                    tok = self._next_token(req, rngs, rows[i, j:j + 1])
                    emitted.append(tok)
                    if j + 1 >= cols or tokens[i, j + 1] != tok:
                        break
                if cols > 1:
                    stats.record_acceptance(req.rid, cols - 1,
                                            len(emitted) - 1)
                done = False
                for tok in emitted:
                    outputs[req.rid].append(tok)
                    stats.tokens_out += 1
                    done = sched.record(i, tok)
                emitted_step += len(emitted)
                if done:
                    if spec:                      # slot may be re-admitted
                        spec["frontier"].pop(i, None)
                elif cols > 1:
                    # pages past the acceptance point backed only rejected
                    # draft positions: return them now (finished lanes
                    # released everything inside record()); the draft
                    # write cursor clamps back too -- draft KV past the
                    # acceptance point is rejected-token garbage the
                    # stream overwrites in place
                    sched.rollback_speculation(i)
                    if spec:
                        f = spec["frontier"]
                        f[i] = min(f.get(i, s.pos), s.pos)
            if spec_lanes:
                stats.spec_steps += 1
            dt = time.time() - t0
            # chunk-carrying steps are prefill-side: their time AND their
            # sampled tokens (first tokens plus any decode lanes riding the
            # step) leave the decode rate, so decode_tok_per_s measures the
            # steady-state decode batch -- comparable across modes
            if plan["chunked"]:
                stats.prefill_s += dt
                stats.prefill_tokens += emitted_step
            else:
                stats.decode_s += dt
            stats.steps += 1
            stats.peak_pages = max(stats.peak_pages,
                                   num_pages - 1 - sched.allocator.n_free)

    # ------------------------------------------------- speculative drafting
    def _make_draft(self, max_slots, num_pages, page_size, draft_k,
                    draft_policy, draft_layers, draft_act_bits):
        """Build the draft pass state for one speculative ``run()``.

        The draft is *another view of the same engine*: it proposes tokens
        through the very ``model_step`` the target verifies with, against
        its own paged cache that shares the main stream's block tables
        (same positions, same page ids -- rollback and scrub cover both).

        * ``"prefix"``: the first ``draft_layers`` stacked repeats of the
          served params (``LM.draft_prefix_params``) -- no extra weights,
          cache stacked to the prefix depth.  ``draft_layers == n_repeat``
          makes the draft the target (acceptance 1.0, the bench ceiling).
        * ``"lowbit"``: the full model as its own cheap proxy, AutoQ
          style -- ``draft_act_bits`` activation QBNs everywhere and an
          int8-KV draft cache, so the draft pays low-bit compute/traffic
          for the same depth.
        """
        model, cfg = self.model, self.model.cfg
        if draft_policy == "prefix":
            d = draft_layers if draft_layers is not None \
                else max(1, cfg.n_repeat // 2)
            params = model.draft_prefix_params(self.params, d)
            act = None if self.act_bits is None else self.act_bits[:d]
            dcache = model.init_paged_cache(
                max_slots, num_pages, page_size, dtype=self.cache_dtype,
                kv_bits=self.kv_bits, n_repeat=d)
        else:                                     # "lowbit"
            params = self.params
            act = jnp.full((cfg.n_repeat, len(cfg.pattern)),
                           4.0 if draft_act_bits is None
                           else float(draft_act_bits), jnp.float32)
            dcache = model.init_paged_cache(
                max_slots, num_pages, page_size, dtype=self.cache_dtype,
                kv_bits=8)
        return {"params": params, "cache": dcache, "act": act, "k": draft_k,
                "frontier": {}}

    def _draft_propose(self, spec, plan, sched, spec_lanes, w1):
        """Run the draft pass for one step; returns slot -> draft tokens.

        Call 1 carries three kinds of rows: prompt-chunk rows keep the
        draft cache's prompt KV warm (without this, chunks fed while no
        lane was speculating would leave holes and crater acceptance);
        every decode row feeds its feedback token, *preceded by a one-token
        catch-up when the previous verify step accepted its whole span*
        (the last draft was proposed but never fed back, so the draft
        cache trails the stream by one position -- ``spec["frontier"]``
        tracks each lane's draft write cursor; after a rejection the
        frontier clamps back, because everything past the acceptance
        point is rejected-token KV that the stream overwrites in place);
        and each speculating row's last-real-column logits propose its
        first draft token.  Calls 2..span-1 are (R, 1) steps feeding each
        lane's previous proposal at the next position -- exactly the
        autoregressive loop the verify step collapses.  Draft proposals
        are greedy by design: the draft is a guess, the verify sampler is
        the ground truth.  ``w1`` is call 1's width (the chunk width, or
        2 on chunkless steps -- feedback plus the catch-up column), so
        the draft compiles two bounded shapes, like the main loop."""
        n = plan["tokens"].shape[0]
        tables = jnp.asarray(sched.tables.as_array())
        slot_map = jnp.asarray(plan["slot_map"])
        frontier = spec.setdefault("frontier", {})
        dtok = np.zeros((n, w1), np.int32)
        dpos = np.full((n, w1), paged_kv.POS_SENTINEL, np.int32)
        lcols = np.zeros((n,), np.int32)
        for i, c in plan["chunked"].items():      # mirror prompt chunks
            dtok[i, :c] = plan["tokens"][i, :c]
            dpos[i, :c] = plan["positions"][i, :c]
            lcols[i] = c - 1
        for i in plan["spec"]:                    # decode rows (any span)
            s = sched.slot(i)
            catch = min(s.pos - frontier.get(i, s.pos), 1)
            if catch:                             # re-feed the accepted
                dtok[i, 0] = s.out[s.pos - 1 - s.req.prompt_len]
                dpos[i, 0] = s.pos - 1            # last draft of last span
            dtok[i, catch] = s.out[-1]
            dpos[i, catch] = s.pos
            lcols[i] = catch
        logits, spec["cache"] = self._draft_step(
            spec["params"], jnp.asarray(dtok), jnp.asarray(dpos), slot_map,
            spec["cache"], tables, jnp.asarray(lcols), spec["act"],
            attn_impl=self.attn_impl)
        prop = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)
        drafts = {i: [prop[i]] for i in spec_lanes}
        max_cols = max(spec_lanes.values(), default=1)
        zeros = jnp.zeros((n,), jnp.int32)
        for m in range(1, max_cols - 1):          # propose d_{m+1}
            # width 2 (second column sentinel) so proposal calls share the
            # chunkless call-1 variant: two draft shapes total
            ctok = np.zeros((n, 2), np.int32)
            cpos = np.full((n, 2), paged_kv.POS_SENTINEL, np.int32)
            for i, cols in spec_lanes.items():
                if cols >= m + 2:                 # lane still drafting
                    ctok[i, 0] = drafts[i][m - 1]
                    cpos[i, 0] = sched.slot(i).pos + m
            logits, spec["cache"] = self._draft_step(
                spec["params"], jnp.asarray(ctok), jnp.asarray(cpos),
                slot_map, spec["cache"], tables, zeros, spec["act"],
                attn_impl=self.attn_impl)
            prop = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)
            for i, cols in spec_lanes.items():
                if cols >= m + 2:
                    drafts[i].append(prop[i])
        for i, cols in plan["spec"].items():      # draft write cursors
            frontier[i] = sched.slot(i).pos + max(cols - 1, 1)
        return {i: np.asarray(d, np.int32) for i, d in drafts.items()}

    def _run_monolithic(self, reqs, sched, cache, kinds, outputs, rngs,
                        stats, num_pages, page_size, reclaim):
        """Legacy prefill-then-decode state machine (hybrid archs; TTFT
        baseline for the chunked loop)."""
        t_run = time.time()
        while sched.has_work:
            # ---- admission: prefill queued requests into free slots/pages
            admitted = 0
            while (adm := sched.try_admit()) is not None:
                admitted += 1
                req, slot, pages = adm
                t0 = time.time()
                logits, dense = self._prefill_one(req, page_size)
                cache = paged_kv.scrub_pages(cache, kinds, pages)
                cache = paged_kv.write_prefill(cache, dense, kinds, slot,
                                               pages, page_size)
                tok = self._next_token(req, rngs, np.asarray(logits[:, -1]))
                stats.prefill_s += time.time() - t0
                outputs[req.rid].append(tok)
                stats.tokens_out += 1
                stats.prefill_tokens += 1
                stats.mono_prefill_tokens += req.prompt_len
                stats.ttft_steps[req.rid] = stats.steps + 1
                stats.ttft_s[req.rid] = time.time() - t_run
                sched.bind(slot, req, tok)
            stats.peak_pages = max(stats.peak_pages,
                                   num_pages - 1 - sched.allocator.n_free)

            running = sched.running_slots()
            if not running:
                if sched.has_work and not admitted:
                    raise paged_kv.PagesExhausted(
                        "queued request cannot ever be admitted: pool of "
                        f"{num_pages} pages (page_size={page_size}) is too "
                        "small for its prompt + decode headroom")
                continue                    # everything admitted finished

            # ---- one batched decode step over all in-flight sequences
            # reclaim outside the timed section, like the chunked loop, so
            # decode_s compares like-for-like across modes
            if reclaim is not None:
                stats.reclaimed_pages += len(
                    sched.reclaim_out_of_window(reclaim))
            t0 = time.time()
            fresh = sched.ensure_pages()
            cache = paged_kv.scrub_pages(cache, kinds, fresh)
            b = sched.batch()
            logits, cache = self._decode_paged(
                self.params, jnp.asarray(b["tokens"]), cache,
                jnp.asarray(b["block_tables"]), jnp.asarray(b["pos"]),
                self.act_bits, attn_impl=self.attn_impl)
            rows = np.asarray(logits[:, -1])
            for i in running:
                req = sched.slot(i).req
                tok = self._next_token(req, rngs, rows[i:i + 1])
                outputs[req.rid].append(tok)
                stats.tokens_out += 1
                sched.record(i, tok)
            stats.decode_s += time.time() - t0
            stats.steps += 1

    # ---------------------------------------------------------- run helpers
    @staticmethod
    def _as_request(rid: int, r) -> Request:
        if isinstance(r, Request):
            return dataclasses.replace(r, rid=rid)
        if isinstance(r, dict):
            return Request(rid=rid, tokens=r["tokens"], n_new=r["n_new"],
                           temperature=r.get("temperature", 0.0),
                           seed=r.get("seed", 0))
        tokens, n_new = r
        return Request(rid=rid, tokens=tokens, n_new=n_new)

    def _prefill_one(self, req: Request, page_size: int):
        """Batch-1 prefill into a dense cache sized to whole pages.

        The cache length only pads the KV store (prefill logits are computed
        from the in-flight k/v, not read back), so rounding the prompt up to
        a page multiple bounds jit variants without changing numerics."""
        L = paged_kv.pages_needed(req.prompt_len, page_size) * page_size
        dense = self.model.init_cache(1, L, dtype=self.cache_dtype,
                                      kv_bits=self.kv_bits)
        logits, dense = self._prefill(
            self.params, {"tokens": jnp.asarray(req.tokens[None])}, dense,
            self.act_bits, attn_impl=self.attn_impl)
        return logits, dense

    def _next_token(self, req: Request, rngs: Dict[int, jax.Array],
                    logits_row: np.ndarray) -> int:
        """Sample/argmax one token, per-request rng stream (matches a
        single-request generate(seed=req.seed) split-for-split)."""
        if req.temperature > 0:
            rng = rngs.get(req.rid)
            if rng is None:
                rng = jax.random.PRNGKey(req.seed)
            rng, k = jax.random.split(rng)
            rngs[req.rid] = rng
            tok = jax.random.categorical(
                k, jnp.asarray(logits_row).astype(jnp.float32)
                / req.temperature, -1)
            return int(np.asarray(tok)[0])
        return int(np.argmax(logits_row[0]))
