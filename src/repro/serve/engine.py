"""Serving engine: single-batch prefill/decode plus continuous batching.

Two execution models share one weight store and one model:

* :meth:`ServeEngine.generate` -- the original batch-at-a-time path: one
  dense ``[B, max_len]`` KV cache, every sequence prefilled together, the
  whole batch decoded in lockstep.  It is the *oracle*: the paged path must
  reproduce its token streams per request.
* :meth:`ServeEngine.run` -- continuous batching over a paged KV cache
  with a unified token-budget step loop (``prefill="chunked"``, default):
  requests are admitted as soon as their *first prompt chunk* fits
  (serve/scheduler.py), and one jit'd ``model_step`` per iteration
  advances every in-flight sequence -- each contributing up to
  ``chunk_tokens`` prompt-chunk tokens or 1 decode token, K/V written
  straight into block-table pages (serve/paged_kv.py).  jit variants are
  bounded per (max_slots, chunk, pool shape), independent of prompt
  lengths.  ``prefill="monolithic"`` keeps the legacy
  prefill-then-decode state machine (batch-1 prefill scattered into the
  pool + ``decode_step_paged``): the only mode for hybrid mamba /
  cross-attention patterns, and the chunked mode's TTFT baseline.

AutoQ integration: the engine deploys a searched :class:`QuantPolicy` at
weight-load time, with per-layer dispatch between two weight stores:

* ``weight_store="fake"`` -- fake-quantized f32 tensors (search-time
  numerics, full-size HBM footprint);
* ``weight_store="packed"`` -- the bucketed sub-byte layout
  (quant.apply.apply_policy_packed): channels with QBN <= 4 bit-packed
  along K (kernels/pack.py), 5..8 int8, > 8 bf16, so stored bytes track the
  searched policy.  ``models.layers.deq`` unpacks at use; on TPU the unpack
  fuses into the consuming matmul (kernels/packed_matmul.py is the
  explicit-tiling version, benchmarked in benchmarks/packed_vs_int8.py).

Both stores serve through *both* execution models unchanged -- the store is
a property of the parameters, not of the cache layout (invariant guarded by
tests/test_paged_kv.py parity tests).

Attention runs on the Pallas kernels by default (``attn_impl="pallas"``:
kernels/attention.py -- fused flash prefill + block-table paged decode, in
interpret mode off-TPU); ``attn_impl="ref"`` is the escape hatch back to
the jnp oracle path, which is also what the train/dry-run paths use.

Activation quantization: a policy's per-block activation QBNs are threaded
into prefill and decode (``serve_act_bits``, on by default), closing the
search->serve gap for activations the same way the weight stores close it
for weights.  ``kv_bits=8`` extends the int8 KV cache to the paged pool
(scale page per KV page; the Pallas decode kernel dequantizes in VMEM).
Everything still runs on a laptop CPU and under a production mesh unchanged
(the dry-run lowers the same prefill/decode steps against the 256/512-chip
meshes).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.pack import PackedWeight
from repro.models.transformer import LM
from repro.quant.apply import apply_policy_packed, apply_policy_to_params
from repro.quant.policy import QuantPolicy
from repro.serve import paged_kv
from repro.serve.scheduler import Request, Scheduler


@dataclasses.dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0
    # tokens excluded from the decode rate: first tokens (sampled off prompt
    # logits) and, in chunked mode, decode tokens riding chunk-carrying
    # steps (whose time is accounted as prefill)
    prefill_tokens: int = 0
    steps: int = 0                  # engine steps (run(): batched steps)
    n_requests: int = 0
    mode: str = ""                  # run(): "chunked" | "monolithic"
    # prompt-token accounting by prefill style (how each prompt token was
    # pushed through the model): budgeted chunks vs batch-1 monolithic
    chunk_prefill_tokens: int = 0
    mono_prefill_tokens: int = 0
    # per-request time-to-first-token, keyed by request id: the 1-based
    # index of the model call whose logits produced the first token
    # (chunked: the step that completed the prompt; monolithic: the
    # admission prefill, counted as if it were the next step -- same
    # convention, so step-based TTFT compares across modes), and
    # wall-clock seconds since run() started
    ttft_steps: Dict[int, int] = dataclasses.field(default_factory=dict)
    ttft_s: Dict[int, float] = dataclasses.field(default_factory=dict)
    requeues: int = 0               # chunked: prefills preempted + requeued
    reclaimed_pages: int = 0        # out-of-window pages returned mid-run
    peak_pages: int = 0             # high-water mark of pool pages in use

    @property
    def decode_tok_per_s(self) -> float:
        # tokens and time of prefill / chunk-carrying steps are excluded on
        # both sides, so this is the steady-state decode-batch rate
        return ((self.tokens_out - self.prefill_tokens) / self.decode_s
                if self.decode_s else 0.0)

    def ttft_percentiles(self, qs=(50, 99)) -> Dict[int, float]:
        """Percentiles of per-request TTFT seconds (empty dict if unset)."""
        if not self.ttft_s:
            return {}
        vals = np.asarray(sorted(self.ttft_s.values()))
        return {q: float(np.percentile(vals, q)) for q in qs}


class ServeEngine:
    def __init__(self, model: LM, params, policy: Optional[QuantPolicy] = None,
                 graph=None, max_len: int = 512, cache_dtype=jnp.float32,
                 weight_store: str = "fake", attn_impl: str = "pallas",
                 kv_bits: Optional[int] = None, serve_act_bits: bool = True):
        """attn_impl: attention backend for every engine model call
        (``"pallas"`` default / ``"ref"`` oracle escape hatch).  kv_bits=8
        stores the KV cache -- dense and paged alike -- as int8 with
        per-(position, head) scales.  serve_act_bits: thread the policy's
        per-block activation QBNs into prefill/decode (no-op without a
        policy)."""
        if weight_store not in ("fake", "packed"):
            raise ValueError(f"unknown weight_store {weight_store!r}")
        if weight_store == "packed" and policy is None:
            raise ValueError("weight_store='packed' requires a policy "
                             "(without one the engine would silently serve "
                             "dense full-precision weights)")
        from repro.models.layers import ATTN_IMPLS
        if attn_impl not in ATTN_IMPLS:
            raise ValueError(f"unknown attn_impl {attn_impl!r}; "
                             f"expected one of {ATTN_IMPLS}")
        if kv_bits not in (None, 8):
            raise ValueError(f"unsupported kv_bits {kv_bits!r}: only 8 "
                             "(int8 + per-(position, head) scales) is "
                             "implemented; None serves full-precision KV")
        self.model = model
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.weight_store = weight_store
        self.attn_impl = attn_impl
        self.kv_bits = kv_bits
        self.act_bits = None
        if policy is not None:
            graph = graph or model.graph(seq_len=1, batch=1)
            if weight_store == "packed":
                params = apply_policy_packed(params, graph, policy)
            else:
                params = apply_policy_to_params(params, graph, policy)
            if serve_act_bits:
                # the same policy -> per-block collapse the evaluator uses,
                # so serving quantizes activations exactly like search-time
                # evaluation (block scalar = input projection site's QBN)
                from repro.quant.linear_quant import FULL_BITS
                self.act_bits = model.block_act_bits(
                    graph, [policy.act_bits.get(l.name, float(FULL_BITS))
                            for l in graph.layers])
        self.params = params
        # trace counters: each jit *trace* (i.e. each compiled variant) runs
        # the python wrapper once, cache hits never do -- so these count
        # compiled variants per entry point.  The chunked step loop is
        # designed to keep trace_counts["model_step"] independent of the
        # number of distinct prompt lengths (regression-tested).
        self.trace_counts: Dict[str, int] = collections.Counter()

        def counted(name, fn):
            @functools.wraps(fn)
            def wrapped(*a, **kw):
                self.trace_counts[name] += 1
                return fn(*a, **kw)
            return wrapped

        self._prefill = jax.jit(counted("prefill", model.prefill),
                                static_argnames=("attn_impl",))
        self._decode = jax.jit(counted("decode_step", model.decode_step),
                               static_argnames=("attn_impl",))
        self._decode_paged = jax.jit(
            counted("decode_step_paged", model.decode_step_paged),
            static_argnames=("attn_impl",))
        self._model_step = jax.jit(counted("model_step", model.model_step),
                                   static_argnames=("attn_impl",))

    def weight_hbm_bytes(self) -> Dict[str, int]:
        """Stored weight bytes by leaf kind.

        ``packed`` counts PackedWeight buffers + scales (the sub-byte
        store); ``int8`` counts {"q","s"} leaves; ``dense`` everything else.
        The packed total is what a searched 4-bit-average policy's HBM
        weight traffic actually costs -- the quantity core/roofline.py's
        reward models."""
        out = {"packed": 0, "int8": 0, "dense": 0}
        leaves = jax.tree_util.tree_leaves_with_path(
            self.params, is_leaf=lambda x: isinstance(x, PackedWeight))
        for path, leaf in leaves:
            if isinstance(leaf, PackedWeight):
                out["packed"] += leaf.hbm_bytes()
            elif any(getattr(p, "key", None) in ("q", "s") for p in path):
                out["int8"] += leaf.size * leaf.dtype.itemsize
            else:
                out["dense"] += leaf.size * leaf.dtype.itemsize
        out["total"] = out["packed"] + out["int8"] + out["dense"]
        return out

    # --------------------------------------------------------- single batch
    def generate(self, tokens: np.ndarray, n_new: int,
                 temperature: float = 0.0, seed: int = 0
                 ) -> Dict[str, Any]:
        """tokens: (B, S_prompt) int32.  Greedy (T=0) or sampled decode."""
        B, S = tokens.shape
        assert S + n_new <= self.max_len
        cache = self.model.init_cache(B, self.max_len, dtype=self.cache_dtype,
                                      kv_bits=self.kv_bits)
        stats = ServeStats(n_requests=B)
        t0 = time.time()
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(tokens)}, cache,
                                      self.act_bits,
                                      attn_impl=self.attn_impl)
        logits.block_until_ready()
        stats.prefill_s = time.time() - t0

        rng = jax.random.PRNGKey(seed)
        out = []
        t0 = time.time()
        cur = None
        for i in range(n_new):
            if temperature > 0:
                rng, k = jax.random.split(rng)
                cur = jax.random.categorical(
                    k, logits[:, -1].astype(jnp.float32) / temperature, -1)
            else:
                cur = jnp.argmax(logits[:, -1], -1)
            cur = cur.astype(jnp.int32)[:, None]
            out.append(np.asarray(cur))
            logits, cache = self._decode(self.params, cur, cache,
                                         jnp.int32(S + i), self.act_bits,
                                         attn_impl=self.attn_impl)
        jax.block_until_ready(logits)
        stats.decode_s = time.time() - t0
        stats.tokens_out = B * n_new
        stats.steps = n_new
        return {"tokens": np.concatenate(out, axis=1), "stats": stats}

    # --------------------------------------------------- continuous batching
    def run(self, requests: Sequence[Union[Request, Dict[str, Any], tuple]],
            *, page_size: int = 16, max_slots: int = 8,
            num_pages: Optional[int] = None, prefill: Optional[str] = None,
            chunk_tokens: Optional[int] = None,
            token_budget: Optional[int] = None) -> Dict[str, Any]:
        """Serve a workload of mixed-length requests with continuous batching.

        requests: each a :class:`Request`, a ``{"tokens", "n_new",
        "temperature"?, "seed"?}`` dict, or a ``(tokens, n_new)`` tuple;
        ``tokens`` is a 1-D prompt.  Per-request greedy/sampled decode
        follows the same rng discipline as a single-request
        :meth:`generate` call with that request's seed, so greedy outputs
        are comparable token-for-token against independent ``generate``
        calls -- under *either* prefill mode:

        * ``prefill="chunked"`` (default where supported): the unified
          token-budget step loop.  Prefill and decode are one jit'd
          ``model_step`` per iteration; each in-flight sequence contributes
          up to ``chunk_tokens`` prompt-chunk tokens or 1 decode token,
          bounded by ``token_budget`` real tokens per step, and prompt K/V
          is written straight into block-table pages (no batch-1 dense
          prefill, no per-prompt-length jit variants).  A request is
          admitted as soon as its *first chunk* fits.  Requires every cache
          kind to be ``"paged"`` (pure attention patterns).
        * ``prefill="monolithic"``: the legacy state machine -- one batch-1
          full-prompt prefill per admitted request scattered into the pool,
          then batched single-token decode steps.  The only mode for hybrid
          (mamba / cross-attention) patterns, whose recurrent state cannot
          chunk; kept as the TTFT baseline for the chunked path
          (benchmarks/continuous_batching.py).

        ``prefill=None`` auto-selects chunked where supported.
        chunk_tokens defaults to ``page_size``; token_budget to
        ``max_slots + chunk_tokens - 1`` (every decode lane plus one full
        chunk) and must be >= max_slots so decode lanes are never starved.

        page_size: KV positions per page.  max_slots: decode-batch width
        (compiled shape).  num_pages: pool size; default sizes for the
        worst case (``max_slots`` sequences at ``max_len``), which can never
        stall.  A smaller pool throttles admission (a request is admitted
        when its prompt -- chunked: first chunk -- plus one page of decode
        headroom fits); in chunked mode a sequence that cannot grow
        mid-*prefill* is preempted and requeued (it has emitted nothing, so
        its restarted stream is unchanged), and prefilling sequences are
        preempted to keep *decode* lanes growing.  Only when nothing is
        left to preempt -- the pool cannot back the running set's decode
        growth, or a lone request can never fit -- does
        :class:`~.paged_kv.PagesExhausted` propagate (in monolithic mode it
        still propagates on any mid-run growth failure, as before).  For
        all-sliding-window patterns, pages that fall wholly out of every
        future attention window are reclaimed at each step boundary, so
        pool occupancy is O(window) per sequence, not O(generated length).

        Returns ``{"outputs": [np.ndarray per request, submit order],
        "stats": ServeStats}`` (with per-request TTFT in ``stats``).
        """
        reqs = [self._as_request(i, r) for i, r in enumerate(requests)]
        for r in reqs:
            if r.prompt_len + r.n_new > self.max_len:
                raise ValueError(
                    f"request {r.rid}: {r.prompt_len}+{r.n_new} tokens "
                    f"exceeds max_len={self.max_len}")
        kinds = self.model.cfg.cache_kinds()
        chunkable = all(kd == "paged" for kd in kinds)
        if prefill is None:
            prefill = "chunked" if chunkable else "monolithic"
        if prefill not in ("chunked", "monolithic"):
            raise ValueError(f"unknown prefill mode {prefill!r}")
        if prefill == "chunked" and not chunkable:
            raise ValueError(
                f"prefill='chunked' needs all-paged cache kinds, got "
                f"{kinds}: recurrent/memory blocks cannot chunk -- use "
                "prefill='monolithic'")
        blocks_per_seq = paged_kv.pages_needed(self.max_len, page_size)
        if num_pages is None:
            num_pages = max_slots * blocks_per_seq + 1      # +1: trash page
        cache = self.model.init_paged_cache(max_slots, num_pages, page_size,
                                            dtype=self.cache_dtype,
                                            kv_bits=self.kv_bits)
        sched = Scheduler(max_slots, page_size,
                          blocks_per_seq, paged_kv.PageAllocator(num_pages))
        for r in reqs:
            sched.submit(r)
        outputs: Dict[int, List[int]] = {r.rid: [] for r in reqs}
        rngs: Dict[int, jax.Array] = {}
        stats = ServeStats(n_requests=len(reqs), mode=prefill)
        # out-of-window reclamation is sound only when *every* block of the
        # pattern attends through the same sliding window (a single global
        # block needs the whole history; one block table serves all layers)
        cfg = self.model.cfg
        reclaim = cfg.window if (chunkable and cfg.window is not None and
                                 all(b.kind == "local_attn"
                                     for b in cfg.pattern)) else None
        args = (reqs, sched, cache, kinds, outputs, rngs, stats, num_pages,
                page_size, reclaim)
        if prefill == "chunked":
            chunk = chunk_tokens if chunk_tokens is not None else page_size
            budget = token_budget if token_budget is not None \
                else max_slots + chunk - 1
            if chunk < 1:
                raise ValueError(f"chunk_tokens must be >= 1, got {chunk}")
            if budget < max_slots:
                raise ValueError(
                    f"token_budget={budget} < max_slots={max_slots}: every "
                    "decode lane needs a token each step (decode is never "
                    "deferred); raise the budget or shrink the batch")
            self._run_chunked(*args, chunk=chunk, budget=budget)
        else:
            self._run_monolithic(*args)
        return {"outputs": [np.asarray(outputs[r.rid], np.int32)
                            for r in reqs],
                "stats": stats}

    def _run_chunked(self, reqs, sched, cache, kinds, outputs, rngs, stats,
                     num_pages, page_size, reclaim, *, chunk, budget):
        """The unified token-budget step loop (prefill == decode)."""
        t_run = time.time()
        while sched.has_work:
            if reclaim is not None:
                stats.reclaimed_pages += len(
                    sched.reclaim_out_of_window(reclaim))
            # ---- admission: a request joins when its first chunk fits
            fresh = []
            while (adm := sched.try_admit_chunked(chunk)) is not None:
                fresh += adm[2]
            if not sched.running_slots():
                raise paged_kv.PagesExhausted(
                    "queued request cannot ever be admitted: pool of "
                    f"{num_pages} pages (page_size={page_size}) is too "
                    "small for its first chunk + decode headroom")
            t0 = time.time()
            plan = sched.plan_step(chunk, budget)
            stats.requeues += len(plan["requeued"])
            # a request admitted above may have been preempted inside this
            # very plan_step: its admission pages are back on the free list
            # (possibly re-allocated -- then they are in plan["fresh"] under
            # the new owner), so drop the stale aliases from the scrub set
            drop = set(plan["freed"])
            fresh = [p for p in fresh if p not in drop]
            # scrub unconditionally: admission pages must be sentinel-clean
            # before any later step writes chunks into them, even if this
            # step is abandoned below
            cache = paged_kv.scrub_pages(cache, kinds, fresh + plan["fresh"])
            if not plan["sample"] and not plan["chunked"]:
                continue            # every planned slot was preempted
            # pure-decode steps run the (R, 1) column slice -- the second
            # (and last) compiled variant; a (R, chunk) step would burn
            # chunk-1 masked lanes per slot once every prompt is in.  jit
            # variants stay 2 per (max_slots, chunk, pool shape), still
            # independent of prompt lengths.
            w = chunk if plan["chunked"] else 1
            logits, cache = self._model_step(
                self.params, jnp.asarray(plan["tokens"][:, :w]),
                jnp.asarray(plan["positions"][:, :w]),
                jnp.asarray(plan["slot_map"]), cache,
                jnp.asarray(sched.tables.as_array()),
                jnp.asarray(plan["logit_cols"]),
                self.act_bits, attn_impl=self.attn_impl)
            rows = np.asarray(logits[:, -1])
            stats.chunk_prefill_tokens += sum(plan["chunked"].values())
            for i in plan["sample"]:
                s = sched.slot(i)
                req = s.req
                tok = self._next_token(req, rngs, rows[i:i + 1])
                outputs[req.rid].append(tok)
                stats.tokens_out += 1
                if not s.out:                     # the request's first token
                    stats.ttft_steps[req.rid] = stats.steps + 1
                    stats.ttft_s[req.rid] = time.time() - t_run
                    sched.record_first(i, tok)
                else:
                    sched.record(i, tok)
            dt = time.time() - t0
            # chunk-carrying steps are prefill-side: their time AND their
            # sampled tokens (first tokens plus any decode lanes riding the
            # step) leave the decode rate, so decode_tok_per_s measures the
            # steady-state (R, 1) decode batch -- comparable across modes
            if plan["chunked"]:
                stats.prefill_s += dt
                stats.prefill_tokens += len(plan["sample"])
            else:
                stats.decode_s += dt
            stats.steps += 1
            stats.peak_pages = max(stats.peak_pages,
                                   num_pages - 1 - sched.allocator.n_free)

    def _run_monolithic(self, reqs, sched, cache, kinds, outputs, rngs,
                        stats, num_pages, page_size, reclaim):
        """Legacy prefill-then-decode state machine (hybrid archs; TTFT
        baseline for the chunked loop)."""
        t_run = time.time()
        while sched.has_work:
            # ---- admission: prefill queued requests into free slots/pages
            admitted = 0
            while (adm := sched.try_admit()) is not None:
                admitted += 1
                req, slot, pages = adm
                t0 = time.time()
                logits, dense = self._prefill_one(req, page_size)
                cache = paged_kv.scrub_pages(cache, kinds, pages)
                cache = paged_kv.write_prefill(cache, dense, kinds, slot,
                                               pages, page_size)
                tok = self._next_token(req, rngs, np.asarray(logits[:, -1]))
                stats.prefill_s += time.time() - t0
                outputs[req.rid].append(tok)
                stats.tokens_out += 1
                stats.prefill_tokens += 1
                stats.mono_prefill_tokens += req.prompt_len
                stats.ttft_steps[req.rid] = stats.steps + 1
                stats.ttft_s[req.rid] = time.time() - t_run
                sched.bind(slot, req, tok)
            stats.peak_pages = max(stats.peak_pages,
                                   num_pages - 1 - sched.allocator.n_free)

            running = sched.running_slots()
            if not running:
                if sched.has_work and not admitted:
                    raise paged_kv.PagesExhausted(
                        "queued request cannot ever be admitted: pool of "
                        f"{num_pages} pages (page_size={page_size}) is too "
                        "small for its prompt + decode headroom")
                continue                    # everything admitted finished

            # ---- one batched decode step over all in-flight sequences
            # reclaim outside the timed section, like the chunked loop, so
            # decode_s compares like-for-like across modes
            if reclaim is not None:
                stats.reclaimed_pages += len(
                    sched.reclaim_out_of_window(reclaim))
            t0 = time.time()
            fresh = sched.ensure_pages()
            cache = paged_kv.scrub_pages(cache, kinds, fresh)
            b = sched.batch()
            logits, cache = self._decode_paged(
                self.params, jnp.asarray(b["tokens"]), cache,
                jnp.asarray(b["block_tables"]), jnp.asarray(b["pos"]),
                self.act_bits, attn_impl=self.attn_impl)
            rows = np.asarray(logits[:, -1])
            for i in running:
                req = sched.slot(i).req
                tok = self._next_token(req, rngs, rows[i:i + 1])
                outputs[req.rid].append(tok)
                stats.tokens_out += 1
                sched.record(i, tok)
            stats.decode_s += time.time() - t0
            stats.steps += 1

    # ---------------------------------------------------------- run helpers
    @staticmethod
    def _as_request(rid: int, r) -> Request:
        if isinstance(r, Request):
            return dataclasses.replace(r, rid=rid)
        if isinstance(r, dict):
            return Request(rid=rid, tokens=r["tokens"], n_new=r["n_new"],
                           temperature=r.get("temperature", 0.0),
                           seed=r.get("seed", 0))
        tokens, n_new = r
        return Request(rid=rid, tokens=tokens, n_new=n_new)

    def _prefill_one(self, req: Request, page_size: int):
        """Batch-1 prefill into a dense cache sized to whole pages.

        The cache length only pads the KV store (prefill logits are computed
        from the in-flight k/v, not read back), so rounding the prompt up to
        a page multiple bounds jit variants without changing numerics."""
        L = paged_kv.pages_needed(req.prompt_len, page_size) * page_size
        dense = self.model.init_cache(1, L, dtype=self.cache_dtype,
                                      kv_bits=self.kv_bits)
        logits, dense = self._prefill(
            self.params, {"tokens": jnp.asarray(req.tokens[None])}, dense,
            self.act_bits, attn_impl=self.attn_impl)
        return logits, dense

    def _next_token(self, req: Request, rngs: Dict[int, jax.Array],
                    logits_row: np.ndarray) -> int:
        """Sample/argmax one token, per-request rng stream (matches a
        single-request generate(seed=req.seed) split-for-split)."""
        if req.temperature > 0:
            rng = rngs.get(req.rid)
            if rng is None:
                rng = jax.random.PRNGKey(req.seed)
            rng, k = jax.random.split(rng)
            rngs[req.rid] = rng
            tok = jax.random.categorical(
                k, jnp.asarray(logits_row).astype(jnp.float32)
                / req.temperature, -1)
            return int(np.asarray(tok)[0])
        return int(np.argmax(logits_row[0]))
