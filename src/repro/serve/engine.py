"""Serving engine: single-batch prefill/decode plus continuous batching.

Two execution models share one weight store and one model:

* :meth:`ServeEngine.generate` -- the original batch-at-a-time path: one
  dense ``[B, max_len]`` KV cache, every sequence prefilled together, the
  whole batch decoded in lockstep.  It is the *oracle*: the paged path must
  reproduce its token streams per request.
* :meth:`ServeEngine.run` -- continuous batching over a paged KV cache:
  requests of mixed lengths are admitted into decode-batch slots as pages
  and slots free up (serve/scheduler.py), prefill runs per admitted request
  and scatters into the page pool (serve/paged_kv.py), and a single jit'd
  ``decode_step_paged`` advances all in-flight sequences one token per step
  through their block tables.

AutoQ integration: the engine deploys a searched :class:`QuantPolicy` at
weight-load time, with per-layer dispatch between two weight stores:

* ``weight_store="fake"`` -- fake-quantized f32 tensors (search-time
  numerics, full-size HBM footprint);
* ``weight_store="packed"`` -- the bucketed sub-byte layout
  (quant.apply.apply_policy_packed): channels with QBN <= 4 bit-packed
  along K (kernels/pack.py), 5..8 int8, > 8 bf16, so stored bytes track the
  searched policy.  ``models.layers.deq`` unpacks at use; on TPU the unpack
  fuses into the consuming matmul (kernels/packed_matmul.py is the
  explicit-tiling version, benchmarked in benchmarks/packed_vs_int8.py).

Both stores serve through *both* execution models unchanged -- the store is
a property of the parameters, not of the cache layout (invariant guarded by
tests/test_paged_kv.py parity tests).

Attention runs on the Pallas kernels by default (``attn_impl="pallas"``:
kernels/attention.py -- fused flash prefill + block-table paged decode, in
interpret mode off-TPU); ``attn_impl="ref"`` is the escape hatch back to
the jnp oracle path, which is also what the train/dry-run paths use.

Activation quantization: a policy's per-block activation QBNs are threaded
into prefill and decode (``serve_act_bits``, on by default), closing the
search->serve gap for activations the same way the weight stores close it
for weights.  ``kv_bits=8`` extends the int8 KV cache to the paged pool
(scale page per KV page; the Pallas decode kernel dequantizes in VMEM).
Everything still runs on a laptop CPU and under a production mesh unchanged
(the dry-run lowers the same prefill/decode steps against the 256/512-chip
meshes).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.pack import PackedWeight
from repro.models.transformer import LM
from repro.quant.apply import apply_policy_packed, apply_policy_to_params
from repro.quant.policy import QuantPolicy
from repro.serve import paged_kv
from repro.serve.scheduler import Request, Scheduler


@dataclasses.dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0
    prefill_tokens: int = 0         # emitted during prefill, timed there
    steps: int = 0                  # decode steps (run(): batched steps)
    n_requests: int = 0

    @property
    def decode_tok_per_s(self) -> float:
        # run() samples each request's first token from the prefill logits
        # (timed in prefill_s), so it must not inflate the decode rate
        return ((self.tokens_out - self.prefill_tokens) / self.decode_s
                if self.decode_s else 0.0)


class ServeEngine:
    def __init__(self, model: LM, params, policy: Optional[QuantPolicy] = None,
                 graph=None, max_len: int = 512, cache_dtype=jnp.float32,
                 weight_store: str = "fake", attn_impl: str = "pallas",
                 kv_bits: Optional[int] = None, serve_act_bits: bool = True):
        """attn_impl: attention backend for every engine model call
        (``"pallas"`` default / ``"ref"`` oracle escape hatch).  kv_bits=8
        stores the KV cache -- dense and paged alike -- as int8 with
        per-(position, head) scales.  serve_act_bits: thread the policy's
        per-block activation QBNs into prefill/decode (no-op without a
        policy)."""
        if weight_store not in ("fake", "packed"):
            raise ValueError(f"unknown weight_store {weight_store!r}")
        if weight_store == "packed" and policy is None:
            raise ValueError("weight_store='packed' requires a policy "
                             "(without one the engine would silently serve "
                             "dense full-precision weights)")
        from repro.models.layers import ATTN_IMPLS
        if attn_impl not in ATTN_IMPLS:
            raise ValueError(f"unknown attn_impl {attn_impl!r}; "
                             f"expected one of {ATTN_IMPLS}")
        if kv_bits not in (None, 8):
            raise ValueError(f"unsupported kv_bits {kv_bits!r}: only 8 "
                             "(int8 + per-(position, head) scales) is "
                             "implemented; None serves full-precision KV")
        self.model = model
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.weight_store = weight_store
        self.attn_impl = attn_impl
        self.kv_bits = kv_bits
        self.act_bits = None
        if policy is not None:
            graph = graph or model.graph(seq_len=1, batch=1)
            if weight_store == "packed":
                params = apply_policy_packed(params, graph, policy)
            else:
                params = apply_policy_to_params(params, graph, policy)
            if serve_act_bits:
                # the same policy -> per-block collapse the evaluator uses,
                # so serving quantizes activations exactly like search-time
                # evaluation (block scalar = input projection site's QBN)
                from repro.quant.linear_quant import FULL_BITS
                self.act_bits = model.block_act_bits(
                    graph, [policy.act_bits.get(l.name, float(FULL_BITS))
                            for l in graph.layers])
        self.params = params
        self._prefill = jax.jit(model.prefill,
                                static_argnames=("attn_impl",))
        self._decode = jax.jit(model.decode_step,
                               static_argnames=("attn_impl",))
        self._decode_paged = jax.jit(model.decode_step_paged,
                                     static_argnames=("attn_impl",))

    def weight_hbm_bytes(self) -> Dict[str, int]:
        """Stored weight bytes by leaf kind.

        ``packed`` counts PackedWeight buffers + scales (the sub-byte
        store); ``int8`` counts {"q","s"} leaves; ``dense`` everything else.
        The packed total is what a searched 4-bit-average policy's HBM
        weight traffic actually costs -- the quantity core/roofline.py's
        reward models."""
        out = {"packed": 0, "int8": 0, "dense": 0}
        leaves = jax.tree_util.tree_leaves_with_path(
            self.params, is_leaf=lambda x: isinstance(x, PackedWeight))
        for path, leaf in leaves:
            if isinstance(leaf, PackedWeight):
                out["packed"] += leaf.hbm_bytes()
            elif any(getattr(p, "key", None) in ("q", "s") for p in path):
                out["int8"] += leaf.size * leaf.dtype.itemsize
            else:
                out["dense"] += leaf.size * leaf.dtype.itemsize
        out["total"] = out["packed"] + out["int8"] + out["dense"]
        return out

    # --------------------------------------------------------- single batch
    def generate(self, tokens: np.ndarray, n_new: int,
                 temperature: float = 0.0, seed: int = 0
                 ) -> Dict[str, Any]:
        """tokens: (B, S_prompt) int32.  Greedy (T=0) or sampled decode."""
        B, S = tokens.shape
        assert S + n_new <= self.max_len
        cache = self.model.init_cache(B, self.max_len, dtype=self.cache_dtype,
                                      kv_bits=self.kv_bits)
        stats = ServeStats(n_requests=B)
        t0 = time.time()
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(tokens)}, cache,
                                      self.act_bits,
                                      attn_impl=self.attn_impl)
        logits.block_until_ready()
        stats.prefill_s = time.time() - t0

        rng = jax.random.PRNGKey(seed)
        out = []
        t0 = time.time()
        cur = None
        for i in range(n_new):
            if temperature > 0:
                rng, k = jax.random.split(rng)
                cur = jax.random.categorical(
                    k, logits[:, -1].astype(jnp.float32) / temperature, -1)
            else:
                cur = jnp.argmax(logits[:, -1], -1)
            cur = cur.astype(jnp.int32)[:, None]
            out.append(np.asarray(cur))
            logits, cache = self._decode(self.params, cur, cache,
                                         jnp.int32(S + i), self.act_bits,
                                         attn_impl=self.attn_impl)
        jax.block_until_ready(logits)
        stats.decode_s = time.time() - t0
        stats.tokens_out = B * n_new
        stats.steps = n_new
        return {"tokens": np.concatenate(out, axis=1), "stats": stats}

    # --------------------------------------------------- continuous batching
    def run(self, requests: Sequence[Union[Request, Dict[str, Any], tuple]],
            *, page_size: int = 16, max_slots: int = 8,
            num_pages: Optional[int] = None) -> Dict[str, Any]:
        """Serve a workload of mixed-length requests with continuous batching.

        requests: each a :class:`Request`, a ``{"tokens", "n_new",
        "temperature"?, "seed"?}`` dict, or a ``(tokens, n_new)`` tuple;
        ``tokens`` is a 1-D prompt.  Per-request greedy/sampled decode
        follows the same rng discipline as a single-request
        :meth:`generate` call with that request's seed, so greedy outputs
        are comparable token-for-token against independent ``generate``
        calls.

        page_size: KV positions per page.  max_slots: decode-batch width
        (compiled shape).  num_pages: pool size; default sizes for the
        worst case (``max_slots`` sequences at ``max_len``), which can never
        stall.  A smaller pool throttles *admission* only -- already-running
        sequences still grow a page at every boundary, and if concurrent
        growth drains the pool mid-run, :class:`~.paged_kv.PagesExhausted`
        propagates and the whole workload's outputs are lost (admission
        headroom reserves one decode page per admit, not the lifetime
        worst case).  Undersize it only for workloads whose total live KV
        provably fits.

        Returns ``{"outputs": [np.ndarray per request, submit order],
        "stats": ServeStats}``.
        """
        reqs = [self._as_request(i, r) for i, r in enumerate(requests)]
        for r in reqs:
            if r.prompt_len + r.n_new > self.max_len:
                raise ValueError(
                    f"request {r.rid}: {r.prompt_len}+{r.n_new} tokens "
                    f"exceeds max_len={self.max_len}")
        blocks_per_seq = paged_kv.pages_needed(self.max_len, page_size)
        if num_pages is None:
            num_pages = max_slots * blocks_per_seq + 1      # +1: trash page
        cache = self.model.init_paged_cache(max_slots, num_pages, page_size,
                                            dtype=self.cache_dtype,
                                            kv_bits=self.kv_bits)
        kinds = self.model.cfg.cache_kinds()
        sched = Scheduler(max_slots, page_size,
                          blocks_per_seq, paged_kv.PageAllocator(num_pages))
        for r in reqs:
            sched.submit(r)

        outputs: Dict[int, List[int]] = {r.rid: [] for r in reqs}
        rngs: Dict[int, jax.Array] = {}
        stats = ServeStats(n_requests=len(reqs))
        while sched.has_work:
            # ---- admission: prefill queued requests into free slots/pages
            admitted = 0
            while (adm := sched.try_admit()) is not None:
                admitted += 1
                req, slot, pages = adm
                t0 = time.time()
                logits, dense = self._prefill_one(req, page_size)
                cache = paged_kv.scrub_pages(cache, kinds, pages)
                cache = paged_kv.write_prefill(cache, dense, kinds, slot,
                                               pages, page_size)
                tok = self._next_token(req, rngs, np.asarray(logits[:, -1]))
                stats.prefill_s += time.time() - t0
                outputs[req.rid].append(tok)
                stats.tokens_out += 1
                stats.prefill_tokens += 1
                sched.bind(slot, req, tok)

            running = sched.running_slots()
            if not running:
                if sched.has_work and not admitted:
                    raise paged_kv.PagesExhausted(
                        "queued request cannot ever be admitted: pool of "
                        f"{num_pages} pages (page_size={page_size}) is too "
                        "small for its prompt + decode headroom")
                continue                    # everything admitted finished

            # ---- one batched decode step over all in-flight sequences
            t0 = time.time()
            fresh = sched.ensure_pages()
            cache = paged_kv.scrub_pages(cache, kinds, fresh)
            b = sched.batch()
            logits, cache = self._decode_paged(
                self.params, jnp.asarray(b["tokens"]), cache,
                jnp.asarray(b["block_tables"]), jnp.asarray(b["pos"]),
                self.act_bits, attn_impl=self.attn_impl)
            rows = np.asarray(logits[:, -1])
            for i in running:
                req = sched.slot(i).req
                tok = self._next_token(req, rngs, rows[i:i + 1])
                outputs[req.rid].append(tok)
                stats.tokens_out += 1
                sched.record(i, tok)
            stats.decode_s += time.time() - t0
            stats.steps += 1

        return {"outputs": [np.asarray(outputs[r.rid], np.int32)
                            for r in reqs],
                "stats": stats}

    # ---------------------------------------------------------- run helpers
    @staticmethod
    def _as_request(rid: int, r) -> Request:
        if isinstance(r, Request):
            return dataclasses.replace(r, rid=rid)
        if isinstance(r, dict):
            return Request(rid=rid, tokens=r["tokens"], n_new=r["n_new"],
                           temperature=r.get("temperature", 0.0),
                           seed=r.get("seed", 0))
        tokens, n_new = r
        return Request(rid=rid, tokens=tokens, n_new=n_new)

    def _prefill_one(self, req: Request, page_size: int):
        """Batch-1 prefill into a dense cache sized to whole pages.

        The cache length only pads the KV store (prefill logits are computed
        from the in-flight k/v, not read back), so rounding the prompt up to
        a page multiple bounds jit variants without changing numerics."""
        L = paged_kv.pages_needed(req.prompt_len, page_size) * page_size
        dense = self.model.init_cache(1, L, dtype=self.cache_dtype,
                                      kv_bits=self.kv_bits)
        logits, dense = self._prefill(
            self.params, {"tokens": jnp.asarray(req.tokens[None])}, dense,
            self.act_bits, attn_impl=self.attn_impl)
        return logits, dense

    def _next_token(self, req: Request, rngs: Dict[int, jax.Array],
                    logits_row: np.ndarray) -> int:
        """Sample/argmax one token, per-request rng stream (matches a
        single-request generate(seed=req.seed) split-for-split)."""
        if req.temperature > 0:
            rng = rngs.get(req.rid)
            if rng is None:
                rng = jax.random.PRNGKey(req.seed)
            rng, k = jax.random.split(rng)
            rngs[req.rid] = rng
            tok = jax.random.categorical(
                k, jnp.asarray(logits_row).astype(jnp.float32)
                / req.temperature, -1)
            return int(np.asarray(tok)[0])
        return int(np.argmax(logits_row[0]))
