"""Serving substrate: request scheduling, paged KV, prefill/decode engines.

Layering (each module is importable on its own):

* :mod:`repro.serve.paged_kv` -- page pool mechanics: free-list allocator,
  per-sequence block tables, scrub-on-alloc and the prefill scatter.  Owns
  the trash-page and position-sentinel invariants.
* :mod:`repro.serve.scheduler` -- continuous-batching policy: chunked
  (first-chunk) and monolithic admission, the token-budget ``plan_step``,
  requeue-on-preemption, out-of-window page reclamation, page lifecycle.
  Pure host-side bookkeeping.
* :mod:`repro.serve.engine` -- :class:`ServeEngine`: quantized weight-store
  deployment (fake-quant or bit-packed) + the two execution models,
  ``generate`` (single dense batch, the oracle) and ``run`` (the unified
  token-budget step loop over the paged pool; chunked prefill by default,
  monolithic fallback for hybrid archs).  Attention runs on the Pallas
  kernels by
  default (``attn_impl="pallas"``, kernels/attention.py; ``"ref"`` is the
  jnp-oracle escape hatch), KV pages optionally int8 (``kv_bits=8``), and
  a policy's activation QBNs follow the model into prefill/decode.

* :mod:`repro.serve.stats` -- :class:`ServeStats`: the measurable
  contract (throughput / TTFT / speculation accounting) both execution
  models fill in.

``run(speculative=True)`` adds multi-token decode: a draft pass (shallow
self-prefix or low-bit rerun of the same packed weights) proposes
``draft_k`` tokens per decoding lane, one verify ``model_step`` scores the
whole span through the paged q-tile kernel, and over-speculated KV pages
roll back the same step -- token streams stay bit-identical to plain
``run()`` for any draft.

See docs/serving.md, docs/attention.md and docs/speculative.md for the
architecture walkthroughs.
"""
from repro.serve.engine import ServeEngine
from repro.serve.paged_kv import PageAllocator, PagesExhausted, pages_needed
from repro.serve.scheduler import Request, Scheduler
from repro.serve.stats import ServeStats

__all__ = ["ServeEngine", "ServeStats", "Request", "Scheduler",
           "PageAllocator", "PagesExhausted", "pages_needed"]
