"""Serving substrate: request scheduling, paged KV, prefill/decode engines.

Layering (each module is importable on its own):

* :mod:`repro.serve.paged_kv` -- page pool mechanics: free-list allocator,
  per-sequence block tables, scrub-on-alloc and the prefill scatter.  Owns
  the trash-page and position-sentinel invariants.
* :mod:`repro.serve.scheduler` -- continuous-batching policy: chunked
  (first-chunk) and monolithic admission, the token-budget ``plan_step``,
  requeue-on-preemption, out-of-window page reclamation, page lifecycle.
  Pure host-side bookkeeping.
* :mod:`repro.serve.engine` -- :class:`ServeEngine`: quantized weight-store
  deployment (fake-quant or bit-packed) + the two execution models,
  ``generate`` (single dense batch, the oracle) and ``run`` (the unified
  token-budget step loop over the paged pool; chunked prefill by default,
  monolithic fallback for hybrid archs).  Attention runs on the Pallas
  kernels by
  default (``attn_impl="pallas"``, kernels/attention.py; ``"ref"`` is the
  jnp-oracle escape hatch), KV pages optionally int8 (``kv_bits=8``), and
  a policy's activation QBNs follow the model into prefill/decode.

See docs/serving.md and docs/attention.md for the architecture walkthrough.
"""
from repro.serve.engine import ServeEngine, ServeStats
from repro.serve.paged_kv import PageAllocator, PagesExhausted, pages_needed
from repro.serve.scheduler import Request, Scheduler

__all__ = ["ServeEngine", "ServeStats", "Request", "Scheduler",
           "PageAllocator", "PagesExhausted", "pages_needed"]
