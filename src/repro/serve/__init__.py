"""Serving substrate: request scheduling, paged KV, prefill/decode engines.

Layering (each module is importable on its own):

* :mod:`repro.serve.paged_kv` -- page pool mechanics: free-list allocator,
  per-sequence block tables, scrub-on-alloc and the prefill scatter.  Owns
  the trash-page and position-sentinel invariants.
* :mod:`repro.serve.scheduler` -- continuous-batching policy: chunked
  (first-chunk) and monolithic admission, the token-budget ``plan_step``,
  requeue-on-preemption, out-of-window page reclamation, page lifecycle.
  Pure host-side bookkeeping; the step plan is one-step-stale tolerant,
  so a pipelined engine can plan ahead of its own token syncs.
* :mod:`repro.serve.frontend` -- :class:`FrontEnd`: the *open-loop*
  request boundary -- timestamped arrivals (live or pre-scheduled),
  per-token stream callbacks, SLO-aware queue shedding.  Injectable
  clock; pure host bookkeeping.
* :mod:`repro.serve.step_loop` -- :class:`StepLoop`: the serving
  back-end -- the token-budget step loop with overlapped dispatch
  (step t+1 planned and dispatched before step t's sampled tokens are
  synced; decode feedback scattered in on device, so it stays exact)
  and the batched on-device sampler.  Speculative decode rides the same
  loop synchronously.
* :mod:`repro.serve.engine` -- :class:`ServeEngine`: quantized
  weight-store deployment (fake-quant or bit-packed) + the execution
  models: ``generate`` (single dense batch, the oracle), ``serve`` (the
  open-loop core: FrontEnd in, StepLoop underneath) and ``run`` (the
  closed-loop compatibility client of ``serve``; monolithic fallback
  for hybrid archs).  Attention runs on the Pallas kernels by default
  (``attn_impl="pallas"``, kernels/attention.py; ``"ref"`` is the
  jnp-oracle escape hatch), KV pages optionally int8 (``kv_bits=8``),
  and a policy's activation QBNs follow the model into prefill/decode.
* :mod:`repro.serve.stats` -- :class:`ServeStats`: the measurable
  contract (throughput / TTFT / open-loop latency / speculation
  accounting) the execution models fill in.

``run(speculative=True)`` adds multi-token decode: a draft pass (shallow
self-prefix or low-bit rerun of the same packed weights) proposes
``draft_k`` tokens per decoding lane, one verify ``model_step`` scores the
whole span through the paged q-tile kernel, and over-speculated KV pages
roll back the same step -- token streams stay bit-identical to plain
``run()`` for any draft.

See docs/serving.md, docs/attention.md and docs/speculative.md for the
architecture walkthroughs.
"""
from repro.serve.engine import ServeEngine
from repro.serve.frontend import FrontEnd
from repro.serve.paged_kv import PageAllocator, PagesExhausted, pages_needed
from repro.serve.scheduler import Request, Scheduler
from repro.serve.stats import ServeStats
from repro.serve.step_loop import StepLoop

__all__ = ["ServeEngine", "ServeStats", "Request", "Scheduler",
           "FrontEnd", "StepLoop", "PageAllocator", "PagesExhausted",
           "pages_needed"]
