"""Serving substrate: batched prefill/decode engine with quantized weights."""
from repro.serve.engine import ServeEngine

__all__ = ["ServeEngine"]
