"""Serving statistics: the measurable contract of ``ServeEngine``.

:class:`ServeStats` is the one record both execution models fill in
(``generate`` partially, ``run`` fully).  It separates three economies:

* **throughput** -- ``tokens_out`` / ``prefill_s`` / ``decode_s``, with
  ``prefill_tokens`` excluding first tokens (and chunk-riding decode
  tokens) from the steady-state ``decode_tok_per_s`` rate;
* **latency** -- per-request ``ttft_steps`` / ``ttft_s`` (1-based index of
  the model call whose logits produced the first token -- the same
  convention in chunked and monolithic modes, so step-based TTFT compares
  across them) and ``ttft_percentiles()``; open-loop serving adds
  ``queue_wait_s`` (arrival -> first admission), ``e2e_s`` (arrival ->
  last token) and the aggregate inter-token gap list ``itl_s``, each with
  a percentile view (``queue_wait_percentiles`` / ``e2e_percentiles`` /
  ``itl_percentiles``).  All wall-clock latency is measured against the
  front-end's clock and a request's *arrival* time -- for the closed-loop
  ``run()`` every request arrives at loop start, so ``ttft_s`` keeps its
  historical "seconds since run() began" meaning;
* **speculation** -- per-request accepted-token histograms
  (``accepted_hist``), ``draft_proposed`` / ``draft_accepted`` (rejected
  draft tokens are counted here and *nowhere else*: they never touch
  ``tokens_out``, TTFT, or the decode rate), ``acceptance_rate`` and
  ``spec_tokens_per_step`` -- the multi-token-decode win
  (docs/speculative.md has the math these feed).

Host-side plain data: no jax arrays, picklable, safe to compare across
runs.  ``serve/engine.py`` re-exports it for backward compatibility.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


def _percentiles(vals, qs) -> Dict[int, float]:
    """Percentile dict over a value collection (empty dict when empty)."""
    vals = sorted(vals)
    if not vals:
        return {}
    arr = np.asarray(vals)
    return {q: float(np.percentile(arr, q)) for q in qs}


@dataclasses.dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0
    # tokens excluded from the decode rate: first tokens (sampled off prompt
    # logits) and, in chunked mode, decode tokens riding chunk-carrying
    # steps (whose time is accounted as prefill)
    prefill_tokens: int = 0
    steps: int = 0                  # engine steps (run(): batched steps)
    n_requests: int = 0
    mode: str = ""                  # run(): "chunked" | "monolithic"
    # prompt-token accounting by prefill style (how each prompt token was
    # pushed through the model): budgeted chunks vs batch-1 monolithic
    chunk_prefill_tokens: int = 0
    mono_prefill_tokens: int = 0
    # per-request time-to-first-token, keyed by request id: the 1-based
    # index of the model call whose logits produced the first token
    # (chunked: the step that completed the prompt; monolithic: the
    # admission prefill, counted as if it were the next step -- same
    # convention, so step-based TTFT compares across modes), and
    # wall-clock seconds since run() started
    ttft_steps: Dict[int, int] = dataclasses.field(default_factory=dict)
    ttft_s: Dict[int, float] = dataclasses.field(default_factory=dict)
    # ---- open-loop latency (arrival-relative; front-end clock) ----
    # arrival -> first slot admission (a requeued prefill keeps its first
    # admission stamp: queue wait measures time to first service)
    queue_wait_s: Dict[int, float] = dataclasses.field(default_factory=dict)
    e2e_s: Dict[int, float] = dataclasses.field(default_factory=dict)
    # aggregate inter-token gaps across requests (time between consecutive
    # tokens *of the same stream* becoming host-visible)
    itl_s: List[float] = dataclasses.field(default_factory=list)
    shed: List[int] = dataclasses.field(default_factory=list)
    overlapped: bool = False        # chunked: pipelined dispatch active
    requeues: int = 0               # chunked: prefills preempted + requeued
    reclaimed_pages: int = 0        # out-of-window pages returned mid-run
    peak_pages: int = 0             # high-water mark of pool pages in use
    # ---- speculative decode (run(speculative=True)) ----
    spec_steps: int = 0             # verify steps with >= 1 speculating lane
    spec_lane_steps: int = 0        # per-lane verify events (lane x step)
    spec_tokens_out: int = 0        # tokens emitted by speculating lanes
    draft_proposed: int = 0         # draft tokens fed into verify chunks
    draft_accepted: int = 0         # of those, accepted into the stream
    # per-request histogram: rid -> {accepted draft count: # verify steps};
    # a lane that emits a+1 tokens in one verify step accepted a drafts
    accepted_hist: Dict[int, Dict[int, int]] = dataclasses.field(
        default_factory=dict)

    @property
    def decode_tok_per_s(self) -> float:
        # tokens and time of prefill / chunk-carrying steps are excluded on
        # both sides, so this is the steady-state decode-batch rate
        return ((self.tokens_out - self.prefill_tokens) / self.decode_s
                if self.decode_s else 0.0)

    @property
    def acceptance_rate(self) -> float:
        """Accepted fraction of proposed draft tokens (0.0 when not
        speculating).  With a draft that bit-agrees with the target
        (draft == model) this is 1.0 -- the sanity ceiling the bench's
        ``--smoke`` gate pins."""
        return (self.draft_accepted / self.draft_proposed
                if self.draft_proposed else 0.0)

    @property
    def spec_tokens_per_step(self) -> float:
        """Emitted tokens per lane per verify step (the multi-token decode
        win; plain decode is 1.0 by construction, the ceiling is
        ``draft_k + 1`` -- every draft accepted plus the free
        continuation token)."""
        return (self.spec_tokens_out / self.spec_lane_steps
                if self.spec_lane_steps else 0.0)

    def record_acceptance(self, rid: int, proposed: int,
                          accepted: int) -> None:
        """Fold one lane's verify-step outcome into the speculation stats
        (``accepted`` drafts matched, so ``accepted + 1`` tokens were
        emitted -- the corrected/continuation token rides for free)."""
        self.spec_lane_steps += 1
        self.draft_proposed += proposed
        self.draft_accepted += accepted
        self.spec_tokens_out += accepted + 1
        hist = self.accepted_hist.setdefault(rid, {})
        hist[accepted] = hist.get(accepted, 0) + 1

    @property
    def n_shed(self) -> int:
        """Requests dropped before first admission (open-loop SLO)."""
        return len(self.shed)

    def ttft_percentiles(self, qs=(50, 99)) -> Dict[int, float]:
        """Percentiles of per-request TTFT seconds (empty dict if unset)."""
        return _percentiles(self.ttft_s.values(), qs)

    def queue_wait_percentiles(self, qs=(50, 99)) -> Dict[int, float]:
        """Percentiles of per-request queue wait (arrival -> admission)."""
        return _percentiles(self.queue_wait_s.values(), qs)

    def e2e_percentiles(self, qs=(50, 99)) -> Dict[int, float]:
        """Percentiles of per-request end-to-end latency (arrival -> last
        token host-visible)."""
        return _percentiles(self.e2e_s.values(), qs)

    def itl_percentiles(self, qs=(50, 99)) -> Dict[int, float]:
        """Percentiles of the aggregate inter-token gap population."""
        return _percentiles(self.itl_s, qs)
