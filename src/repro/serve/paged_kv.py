"""Paged KV cache: fixed-size pages, per-sequence block tables, a free-list.

The continuous-batching engine (serve/engine.py ``run``) stores decode K/V in
a pool of fixed-size pages shared by all in-flight sequences instead of one
dense ``[B, max_len, ...]`` buffer per batch.  Each sequence owns a *block
table* -- logical block ``i`` (token positions ``i*page_size ..
(i+1)*page_size - 1``) maps to a physical page id -- and pages are allocated
from / returned to a free-list as requests start, grow, and finish.  This is
the vLLM paged-attention memory model reduced to its jnp-serving essentials:
no copy-on-write (no beam search here), no swapping, and attention gathers
whole pages through the block table (models/layers.py::paged_attention)
rather than running a per-page kernel.

Invariants the rest of the stack relies on:

* **Page 0 is the trash page.**  It is never handed out by the allocator.
  Unmapped block-table entries (idle slots' whole rows, and every active
  sequence's not-yet-grown tail blocks) point at it, so gathers *do* read
  trash -- which is safe because page 0's position plane is all-sentinel
  and must stay that way: idle decode lanes write with
  ``pos = POS_SENTINEL`` (scheduler.batch), so the only writes that ever
  reach page 0 are themselves unattendable.
* **Position-sentinel scrubbing.**  A page's ``pos`` slots are reset to
  ``POS_SENTINEL`` (int32 max) at *allocation* time (:func:`scrub_pages`).
  K/V bytes from a previous owner may persist, but the causal mask
  ``kv_pos <= q_pos`` rejects sentinel positions, so stale data is
  unreachable.  Freeing is O(1) -- no scrub on release.
* **Layout contract** (built by ``LM.init_paged_cache``, keyed by
  ``LMConfig.cache_kinds()``): ``"paged"`` entries are
  ``{"k","v": (R, P, page_size, Hkv, hd), "pos": (R, P, page_size)}``;
  ``"memory"`` / ``"state"`` entries are the dense per-slot caches with the
  batch axis sized to the number of scheduler slots.  ``R`` is the scan
  stack (n_repeat); all repeats of a block write the same positions, so one
  block table serves every layer.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# the models' sentinel conventions (unwritten/unattendable KV positions;
# the reserved trash page sentinel lanes write into) are the single source
# of truth: the scheduler's idle-lane writes, the pool's scrub value and
# the allocator's reserved page must be bit-equal to what the model's
# attention mask rejects and its paged write path routes to
from repro.models.transformer import POS_SENTINEL, TRASH_PAGE


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Pages required to hold ``n_tokens`` KV positions."""
    return -(-max(n_tokens, 0) // page_size)


class PageAllocator:
    """Free-list allocator over physical page ids ``1 .. num_pages-1``.

    Page 0 (``TRASH_PAGE``) is reserved and never allocated.  ``alloc`` is
    all-or-nothing: it raises :class:`PagesExhausted` rather than returning a
    partial set, so callers either get a usable block run or can keep the
    request queued (admission backpressure).
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, 0, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise PagesExhausted(
                f"requested {n} pages, {len(self._free)} free of "
                f"{self.num_pages - 1} allocatable")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if not (0 < p < self.num_pages):
                raise ValueError(f"bad page id {p}")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
            self._free.append(p)


class PagesExhausted(RuntimeError):
    """Raised when the KV pool cannot back a required allocation."""


class BlockTables:
    """Per-slot logical-block -> physical-page maps, as one int32 array.

    Row ``s`` is slot ``s``'s table; unmapped blocks point at ``TRASH_PAGE``.
    The array view (:meth:`as_array`) is what ``decode_step_paged`` indexes
    with ``pos // page_size`` on device.
    """

    def __init__(self, n_slots: int, blocks_per_seq: int):
        self.blocks_per_seq = blocks_per_seq
        self._table = np.full((n_slots, blocks_per_seq), TRASH_PAGE, np.int32)
        self._held: Dict[int, List[int]] = {s: [] for s in range(n_slots)}

    def held(self, slot: int) -> List[int]:
        """Per-logical-block entries for ``slot``: physical page ids, with
        ``TRASH_PAGE`` placeholders where a leading block was reclaimed
        (:meth:`free_prefix`) -- logical indices never shift."""
        return list(self._held[slot])

    def n_live(self, slot: int) -> int:
        """Physical pages actually held (excludes reclaimed placeholders)."""
        return sum(1 for p in self._held[slot] if p != TRASH_PAGE)

    def n_blocks(self, slot: int) -> int:
        return len(self._held[slot])

    def append(self, slot: int, pages: Sequence[int]) -> None:
        """Map ``pages`` to the next logical blocks of ``slot``."""
        start = len(self._held[slot])
        if start + len(pages) > self.blocks_per_seq:
            raise ValueError(
                f"slot {slot}: {start}+{len(pages)} blocks exceeds "
                f"blocks_per_seq={self.blocks_per_seq}")
        for i, p in enumerate(pages):
            self._table[slot, start + i] = p
        self._held[slot].extend(pages)

    def free_prefix(self, slot: int, upto: int) -> List[int]:
        """Unmap still-held pages of logical blocks ``[0, upto)``.

        Out-of-window reclamation for sliding-window sequences: the freed
        entries become ``TRASH_PAGE`` placeholders in both the table row and
        the held list, so later blocks keep their logical indices (block
        ``i`` must always mean positions ``i*page_size ..``) and gathers of
        the reclaimed range read the all-sentinel trash page.  Returns the
        freed physical pages (caller returns them to the allocator).
        """
        held = self._held[slot]
        freed = []
        for b in range(min(upto, len(held))):
            if held[b] != TRASH_PAGE:
                freed.append(held[b])
                held[b] = TRASH_PAGE
                self._table[slot, b] = TRASH_PAGE
        return freed

    def truncate_to(self, slot: int, n_blocks: int) -> List[int]:
        """Unmap logical blocks ``>= n_blocks`` of ``slot``; return their
        still-held physical pages (caller frees them).

        Speculative-decode rollback: a verify step grows pages out to the
        full draft span up front; after acceptance lands at position
        ``pos``, the scheduler truncates the table back to
        ``pages_needed(pos, page_size)`` blocks -- exactly the blocks
        plain decode would hold at that position -- so over-speculated
        pages return to the pool the same step they were rejected.  The
        tail is the mirror of :meth:`free_prefix`'s head: dropped entries
        shrink the held list (growth re-appends from ``n_blocks``), while
        any reclaimed ``TRASH_PAGE`` placeholders inside the kept prefix
        stay put.  The truncated table entries go back to ``TRASH_PAGE``,
        so gathers of the rolled-back range read the all-sentinel trash
        page; K/V bytes of *kept* pages past ``pos`` are left as-is --
        they carry positions ``> pos`` that the causal mask rejects until
        the stream overwrites them (the rollback invariant,
        docs/speculative.md).
        """
        held = self._held[slot]
        if n_blocks < 0:
            raise ValueError(f"n_blocks must be >= 0, got {n_blocks}")
        freed = [p for p in held[n_blocks:] if p != TRASH_PAGE]
        for b in range(n_blocks, len(held)):
            self._table[slot, b] = TRASH_PAGE
        del held[n_blocks:]
        return freed

    def release(self, slot: int) -> List[int]:
        """Unmap and return the slot's pages (caller frees them; reclaimed
        placeholder blocks are skipped -- their pages were freed already)."""
        pages = [p for p in self._held[slot] if p != TRASH_PAGE]
        self._held[slot] = []
        self._table[slot, :] = TRASH_PAGE
        return pages

    def as_array(self) -> np.ndarray:
        return self._table.copy()


# --------------------------------------------------------- pool operations
def scrub_pages(paged_cache, kinds: Sequence[str], pages: Sequence[int]):
    """Reset ``pos`` of freshly allocated pages to the sentinel.

    Must run between a page leaving the free-list and any gather that could
    see it; K/V bytes are left as-is (masked out by the sentinel positions).
    """
    if not pages:
        return paged_cache
    idx = jnp.asarray(list(pages), jnp.int32)
    out = []
    for kind, entry in zip(kinds, paged_cache):
        if kind == "paged":
            entry = dict(entry)
            entry["pos"] = entry["pos"].at[:, idx].set(POS_SENTINEL)
        out.append(entry)
    return tuple(out)


def write_prefill(paged_cache, dense_cache, kinds: Sequence[str], slot: int,
                  blocks: Sequence[int], page_size: int):
    """Scatter one request's freshly prefilled dense cache into the pool.

    ``dense_cache`` is a batch-1 cache filled by ``LM.prefill``; ``blocks``
    is the slot's physical pages in logical order (must already cover the
    prompt and be scrubbed).  The scatter is driven by the dense cache's own
    ``pos`` plane, so ring-buffer (sliding-window) prefill caches -- which
    hold only the last ``window`` positions -- copy exactly the positions
    they kept.  Every per-slot plane of a ``"paged"`` entry copies the same
    way -- k/v values, ``pos``, and (int8 pools) the ``k_s``/``v_s`` scale
    pages -- so a ``kv_bits=8`` prefill lands in the pool with the exact
    scales the dense quantizer chose.  ``"memory"`` and ``"state"`` entries
    copy whole into batch slot ``slot``.
    """
    blocks_np = np.asarray(list(blocks), np.int32)
    out = []
    for kind, pool, pre in zip(kinds, paged_cache, dense_cache):
        if kind == "paged":
            pos = np.asarray(pre["pos"][0, 0])            # same across R
            j = np.nonzero(pos != POS_SENTINEL)[0]
            p = pos[j]
            phys = jnp.asarray(blocks_np[p // page_size])
            pslot = jnp.asarray(p % page_size)
            j = jnp.asarray(j)
            # pool planes are (R, P, ps, ...) and dense planes (R, 1, S, ...)
            # with matching trailing dims, so one scatter form covers them all
            entry = {key: pool[key].at[:, phys, pslot].set(
                pre[key][:, 0, j].astype(pool[key].dtype)) for key in pool}
            out.append(entry)
        elif kind == "memory":
            out.append({key: pool[key].at[:, slot].set(
                pre[key][:, 0].astype(pool[key].dtype)) for key in pool})
        else:                                             # "state"
            out.append(jax.tree.map(
                lambda pl, pr: pl.at[:, slot].set(pr[:, 0].astype(pl.dtype)),
                pool, pre))
    return tuple(out)
