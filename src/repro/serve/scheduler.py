"""Continuous-batching request scheduler for the paged serving engine.

Iteration-level (Orca-style) scheduling: the decode batch is a fixed array
of *slots*; at every engine step, finished sequences leave their slot and
free their pages, and queued requests are admitted into free slots -- new
work joins the decode batch between single-token steps instead of waiting
for the whole batch to drain.

State machine per request::

    submit() -> QUEUED --admit()--> RUNNING --(n_new tokens)--> FINISHED
                  ^                    |
                  '-- stays queued if no free slot / not enough free pages

Page lifecycle (the scheduler is the only allocator client):

* **admit**: allocates ``ceil(prompt_len / page_size)`` pages for the
  prompt; admission is refused (request stays queued, FIFO order kept)
  unless that many pages *plus one decode page of headroom* are free.
* **decode**: before each engine step, :meth:`ensure_pages` extends any
  running sequence whose next write position crosses a page boundary by one
  page.  If the pool is exhausted here, :class:`~.paged_kv.PagesExhausted`
  propagates -- size the pool for the worst case (the engine's default
  does) or accept admission backpressure as the only throttle.
* **finish/release**: all of the sequence's pages go back to the free-list
  and its block-table row resets to the trash page.

The scheduler is pure host-side bookkeeping (numpy block tables, Python
free-list): it never touches device arrays.  The engine owns jit'd model
calls and asks the scheduler for the batch arrays each step.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.paged_kv import (POS_SENTINEL, BlockTables, PageAllocator,
                                  pages_needed)


@dataclasses.dataclass
class Request:
    """One generation request: prompt tokens + decode budget."""
    rid: int
    tokens: np.ndarray            # (S,) int32 prompt
    n_new: int                    # tokens to generate (>= 1)
    temperature: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.n_new < 1:
            raise ValueError(f"request {self.rid}: n_new must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.size)


@dataclasses.dataclass
class _Slot:
    """Decode-batch slot state for one RUNNING request."""
    req: Request
    pos: int                      # next write position (= tokens seen so far)
    out: List[int]                # emitted tokens

    @property
    def done(self) -> bool:
        return len(self.out) >= self.req.n_new


_RESERVED = object()      # slot handed out by try_admit, awaiting bind()


class Scheduler:
    """Admission queue + slot table + page bookkeeping."""

    def __init__(self, n_slots: int, page_size: int, blocks_per_seq: int,
                 allocator: PageAllocator):
        self.n_slots = n_slots
        self.page_size = page_size
        self.allocator = allocator
        self.tables = BlockTables(n_slots, blocks_per_seq)
        self._queue: Deque[Request] = deque()
        self._slots: List[Optional[_Slot]] = [None] * n_slots
        self.n_finished = 0

    # ------------------------------------------------------------- queries
    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    @property
    def n_running(self) -> int:
        return len(self.running_slots())

    def running_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots)
                if isinstance(s, _Slot)]

    def slot(self, i: int) -> _Slot:
        s = self._slots[i]
        assert isinstance(s, _Slot), f"slot {i} is not running"
        return s

    # ----------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def try_admit(self) -> Optional[Tuple[Request, int, List[int]]]:
        """Admit the queue head if a slot and enough pages are free.

        Returns (request, slot index, prompt pages in logical order), with
        the pages already allocated and mapped, or None if the head must
        wait (FIFO: later, smaller requests never jump the queue -- keeps
        admission starvation-free).  The caller prefills the request,
        scrubs + fills the pages, then calls :meth:`bind`.
        """
        if not self._queue:
            return None
        free_slot = next((i for i, s in enumerate(self._slots) if s is None),
                         None)
        if free_slot is None:
            return None
        req = self._queue[0]
        need = pages_needed(req.prompt_len, self.page_size)
        # positions ever written: 0 .. prompt+n_new-2 (the final emitted
        # token is never fed back), so this is the request's lifetime total
        total = pages_needed(req.prompt_len + req.n_new - 1, self.page_size)
        if self.allocator.n_free < min(need + 1, total):
            return None                          # wait: decode headroom
        self._queue.popleft()
        pages = self.allocator.alloc(need)
        self.tables.append(free_slot, pages)
        self._slots[free_slot] = _RESERVED     # until bind(); never batched
        return req, free_slot, pages

    def bind(self, slot: int, req: Request, first_token: int) -> bool:
        """Install a prefilled request into its slot with its first emitted
        token (sampled from the prefill logits).  Returns True if the
        request is already finished (n_new == 1)."""
        s = _Slot(req=req, pos=req.prompt_len, out=[int(first_token)])
        self._slots[slot] = s
        if s.done:
            self._release(slot)
            return True
        return False

    # -------------------------------------------------------------- decode
    def ensure_pages(self) -> List[int]:
        """Back every running sequence's next write position with a page.

        Returns the newly allocated pages (caller must scrub their ``pos``
        before the decode step).  Raises PagesExhausted if the pool cannot
        grow a running sequence -- admission headroom makes this unreachable
        unless the pool is smaller than one sequence's worst case."""
        fresh: List[int] = []
        for i in self.running_slots():
            s = self.slot(i)
            if s.pos // self.page_size >= self.tables.n_blocks(i):
                page = self.allocator.alloc(1)
                self.tables.append(i, page)
                fresh.extend(page)
        return fresh

    def batch(self) -> Dict[str, np.ndarray]:
        """Fixed-shape decode batch arrays.

        Idle slots carry token 0, an all-trash block-table row, and --
        load-bearing -- ``pos = POS_SENTINEL``: their lanes still execute
        the KV write, and the sentinel both routes it to the trash page
        (block index clips into the all-trash row) and makes the written
        entry unattendable (the causal mask rejects sentinel positions).
        An idle lane must never write a *real* position anywhere, or active
        sequences gathering their own unmapped (trash) blocks would see a
        fake valid KV entry."""
        tokens = np.zeros((self.n_slots, 1), np.int32)
        pos = np.full((self.n_slots,), POS_SENTINEL, np.int32)
        for i in self.running_slots():
            s = self.slot(i)
            tokens[i, 0] = s.out[-1]
            pos[i] = s.pos
        return {"tokens": tokens, "pos": pos,
                "block_tables": self.tables.as_array()}

    def record(self, slot: int, token: int) -> bool:
        """Record one decoded token; returns True (and releases the slot's
        pages) when the request just finished."""
        s = self.slot(slot)
        s.out.append(int(token))
        s.pos += 1
        if s.done:
            self._release(slot)
            return True
        return False

    # ------------------------------------------------------------- release
    def _release(self, slot: int) -> None:
        self.allocator.free(self.tables.release(slot))
        self._slots[slot] = None
        self.n_finished += 1
