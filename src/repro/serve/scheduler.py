"""Continuous-batching request scheduler for the paged serving engine.

Iteration-level (Orca-style) scheduling: the batch is a fixed array of
*slots*; at every engine step, finished sequences leave their slot and
free their pages, and queued requests are admitted into free slots -- new
work joins the batch between steps instead of waiting for the whole batch
to drain.  Two admission styles share the slot table:

* **chunked** (:meth:`try_admit_chunked` + :meth:`plan_step`, the engine
  default): a request is admitted when its *first prompt chunk* fits, and
  the prompt is fed chunk by chunk through the engine's unified
  ``model_step`` under a per-step token budget -- decode lanes take 1
  token each first (or a ``draft_k + 1``-column *speculative verify span*
  when the engine runs multi-token decode; over-speculated tail pages are
  returned post-step by :meth:`rollback_speculation`), the remainder funds
  prompt chunks.  A prefilling sequence whose pages cannot grow is
  preempted and *requeued* (it has emitted nothing, so a restart replays
  the identical stream).
* **monolithic** (:meth:`try_admit` + :meth:`batch`): the legacy path --
  the whole prompt's pages up front, one batch-1 prefill per request
  (hybrid mamba/cross-attn patterns only chunk this way).

State machine per request::

    submit() -> QUEUED --admit--> RUNNING: prefilling --> RUNNING: decoding
                  ^                  | (chunked only)          |
                  |                  '--requeue (preempted)    v
                  '-- stays queued if no free slot /       FINISHED
                      not enough free pages

Page lifecycle (the scheduler is the only allocator client): pages are
allocated at admission (first chunk / whole prompt) and as write positions
cross page boundaries (:meth:`plan_step` / :meth:`ensure_pages`); freed at
finish, at requeue, and -- for all-sliding-window patterns -- as soon as a
page falls wholly behind every future attention window
(:meth:`reclaim_out_of_window`).  Exhaustion mid-growth raises
:class:`~.paged_kv.PagesExhausted` only when no prefilling sequence is
left to preempt.

The scheduler is pure host-side bookkeeping (numpy block tables, Python
free-list): it never touches device arrays.  The engine owns jit'd model
calls and asks the scheduler for the batch arrays each step.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.paged_kv import (POS_SENTINEL, BlockTables, PageAllocator,
                                  PagesExhausted, pages_needed)


@dataclasses.dataclass
class Request:
    """One generation request: prompt tokens + decode budget."""
    rid: int
    tokens: np.ndarray            # (S,) int32 prompt
    n_new: int                    # tokens to generate (>= 1)
    temperature: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.n_new < 1:
            raise ValueError(f"request {self.rid}: n_new must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.size)


@dataclasses.dataclass
class _Slot:
    """Decode-batch slot state for one RUNNING request."""
    req: Request
    pos: int                      # next write position (= tokens seen so far)
    out: List[int]                # emitted tokens
    seq: int = 0                  # admission order stamp (requeue keeps FIFO)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.req.n_new

    @property
    def prefilling(self) -> bool:
        """Chunked admission: prompt tokens still to be fed.  (Monolithic
        admission binds at ``pos == prompt_len``, so it is never True.)"""
        return self.pos < self.req.prompt_len


_RESERVED = object()      # slot handed out by try_admit, awaiting bind()


class Scheduler:
    """Admission queue + slot table + page bookkeeping."""

    def __init__(self, n_slots: int, page_size: int, blocks_per_seq: int,
                 allocator: PageAllocator):
        self.n_slots = n_slots
        self.page_size = page_size
        self.allocator = allocator
        self.tables = BlockTables(n_slots, blocks_per_seq)
        self._queue: Deque[Request] = deque()
        self._slots: List[Optional[_Slot]] = [None] * n_slots
        self.n_finished = 0
        self._admit_seq = 0       # admissions so far (stamps _Slot.seq)

    # ------------------------------------------------------------- queries
    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    @property
    def n_running(self) -> int:
        return len(self.running_slots())

    def running_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots)
                if isinstance(s, _Slot)]

    def slot(self, i: int) -> _Slot:
        s = self._slots[i]
        assert isinstance(s, _Slot), f"slot {i} is not running"
        return s

    # ----------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def drop_queued(self, rid: int) -> bool:
        """Remove a still-queued request (open-loop SLO shedding).

        Only requests that never reached a slot can be dropped -- once
        admitted a request owns pages and (possibly) emitted tokens, and
        shedding it would tear a stream mid-flight.  Returns True iff the
        request was found in the queue and removed."""
        for r in self._queue:
            if r.rid == rid:
                self._queue.remove(r)
                return True
        return False

    def try_admit(self) -> Optional[Tuple[Request, int, List[int]]]:
        """Admit the queue head if a slot and enough pages are free.

        Returns (request, slot index, prompt pages in logical order), with
        the pages already allocated and mapped, or None if the head must
        wait (FIFO: later, smaller requests never jump the queue -- keeps
        admission starvation-free).  The caller prefills the request,
        scrubs + fills the pages, then calls :meth:`bind`.
        """
        if not self._queue:
            return None
        free_slot = next((i for i, s in enumerate(self._slots) if s is None),
                         None)
        if free_slot is None:
            return None
        req = self._queue[0]
        need = pages_needed(req.prompt_len, self.page_size)
        # positions ever written: 0 .. prompt+n_new-2 (the final emitted
        # token is never fed back), so this is the request's lifetime total
        total = pages_needed(req.prompt_len + req.n_new - 1, self.page_size)
        if self.allocator.n_free < min(need + 1, total):
            return None                          # wait: decode headroom
        self._queue.popleft()
        pages = self.allocator.alloc(need)
        self.tables.append(free_slot, pages)
        self._slots[free_slot] = _RESERVED     # until bind(); never batched
        return req, free_slot, pages

    def bind(self, slot: int, req: Request, first_token: int) -> bool:
        """Install a prefilled request into its slot with its first emitted
        token (sampled from the prefill logits).  Returns True if the
        request is already finished (n_new == 1)."""
        s = _Slot(req=req, pos=req.prompt_len, out=[int(first_token)])
        self._slots[slot] = s
        if s.done:
            self._release(slot)
            return True
        return False

    # --------------------------------------------------- chunked admission
    def try_admit_chunked(self, chunk: int
                          ) -> Optional[Tuple[Request, int, List[int]]]:
        """Admit the queue head when its *first chunk* fits.

        Unlike :meth:`try_admit`, admission requires pages for only
        ``min(chunk, prompt_len)`` positions (plus the usual one-page
        headroom, capped at the request's lifetime total) -- a long prompt
        no longer waits for its whole page run to be free.  The slot is
        installed RUNNING immediately with a chunk cursor at position 0;
        the step loop (:meth:`plan_step`) feeds the prompt chunk by chunk
        and samples the first token when the cursor reaches the prompt end.
        Returns (request, slot, first-chunk pages to scrub) or None.
        """
        if not self._queue:
            return None
        free_slot = next((i for i, s in enumerate(self._slots) if s is None),
                         None)
        if free_slot is None:
            return None
        req = self._queue[0]
        need = pages_needed(min(chunk, req.prompt_len), self.page_size)
        total = pages_needed(req.prompt_len + req.n_new - 1, self.page_size)
        if self.allocator.n_free < min(need + 1, total):
            return None                          # wait: chunk + headroom
        self._queue.popleft()
        pages = self.allocator.alloc(need)
        self.tables.append(free_slot, pages)
        self._slots[free_slot] = _Slot(req=req, pos=0, out=[],
                                       seq=self._admit_seq)
        self._admit_seq += 1
        return req, free_slot, pages

    def plan_step(self, chunk: int, token_budget: int,
                  draft_k: int = 0) -> Dict[str, object]:
        """Build one fixed-shape token-budget batch (the *step plan*).

        Every decode-ready slot contributes its feedback token first
        (decode is never starved); with ``draft_k > 0`` each decode lane is
        additionally planned as a **speculative span** of up to
        ``draft_k + 1`` verify columns (feedback + ``draft_k`` draft
        tokens, capped at the request's remaining ``n_new`` and charged in
        full against the budget -- a lane the budget or the pool cannot
        back degrades toward plain 1-token decode, never below it).  The
        remaining budget funds prompt-chunk tokens for prefilling slots in
        slot order, up to ``chunk`` per slot per step (partial chunks are
        fine -- padded columns carry sentinel positions).  Newly needed
        pages are allocated here; if a *chunk* cannot be backed, the
        youngest prefilling slot is requeued (pages freed, request back at
        the queue head -- it has emitted nothing, so a later restart
        reproduces its stream) rather than failing the whole workload; if
        a *decode* token cannot be backed, prefilling slots are requeued
        to free pages first and only then does
        :class:`~.paged_kv.PagesExhausted` propagate (nothing left to
        preempt: the pool is smaller than the running set's worst case).
        Draft columns past the first never preempt anyone -- speculation
        is best-effort, and its tail pages are returned post-step by
        :meth:`rollback_speculation`.

        Returns the **plan dict** -- the engine<->scheduler step contract
        (pinned in docs/serving.md; every key, every step, both consumers):

        ``"tokens"``, ``"positions"`` : (n_slots, W) int32 device-ready
            arrays, ``W = chunk`` (or ``max(chunk, draft_k + 1)`` when
            speculating).  Real tokens left-aligned per row; padding
            carries ``POS_SENTINEL`` positions.  Draft columns (1..span-1
            of a speculating row) are *placeholders* the engine fills
            after the draft pass -- the plan fixes their positions only.
            A decode row's column 0 carries the *host view* of the lane's
            last sampled token, which a pipelined engine may not have
            synced yet (the overlapped step loop records a ``PENDING``
            placeholder and substitutes the exact device-resident token
            at dispatch).  The plan itself is **one-step-stale tolerant**
            by construction: chunk planning, page growth, and preemption
            depend only on token *counts* and positions, never on token
            values, so a stale (or placeholder) feedback value changes
            nothing but the bits the engine overrides anyway.
        ``"slot_map"`` : (n_slots,) int32 row -> scheduler slot (identity
            here; the contract allows compaction).
        ``"logit_cols"`` : (n_slots,) int32 -- each row's last real
            column, whose logits the sampler reads; with ``draft_k > 0``
            shaped (n_slots, draft_k + 1), one column per verify position
            (padded by repeating the last) -- ``model_step``'s 2-D form.
        ``"sample"`` : slots emitting >= 1 token this step -- every decode
            lane, plus each prefilling slot whose chunk reaches its prompt
            end this step (its first token; TTFT).
        ``"decode"`` : the decode-lane subset of ``"sample"`` (slots whose
            column-0 token is *feedback*, i.e. exactly the rows whose
            input an overlapped engine must source from the previous
            step's device-resident sample).
        ``"spec"`` : slot -> planned verify-span width (1..draft_k+1) for
            decode lanes when ``draft_k > 0``, else ``{}``.  Width 1 means
            the lane degraded to plain decode (no draft pass for it).
        ``"chunked"`` : slot -> prompt-chunk tokens fed this step (the
            step is *chunk-carrying* iff non-empty: its wall time and
            sampled tokens are accounted prefill-side).
        ``"fresh"`` : pages allocated this step, still owned by a live
            slot -- the engine must scrub them (sentinel ``pos``) before
            the model call touches the pool.
        ``"freed"`` : pages free-listed by preemptions this step -- the
            engine must drop stale aliases of them (e.g. this step's
            admission pages) from its own scrub set; they may already be
            re-allocated under a new owner in ``"fresh"``.
        ``"requeued"`` : request ids sent back to the queue head (their
            slots vacated; FIFO order preserved).
        """
        n = self.n_slots
        W = chunk if draft_k == 0 else max(chunk, draft_k + 1)
        tokens = np.zeros((n, W), np.int32)
        positions = np.full((n, W), POS_SENTINEL, np.int32)
        logit_cols = np.zeros((n,) if draft_k == 0 else (n, draft_k + 1),
                              np.int32)
        sample: List[int] = []
        fresh: List[int] = []
        freed: List[int] = []
        preempted: List[_Slot] = []
        chunked: Dict[int, int] = {}
        spec: Dict[int, int] = {}
        budget = token_budget

        # decode lanes are never preempted, so this snapshot is stable even
        # while prefilling slots are being vacated to back them
        decode_lanes = [i for i in self.running_slots()
                        if not self._slots[i].prefilling]
        lane_cols: Dict[int, int] = {}
        # draft-tail pages granted this step, per lane: (first col using
        # the page, page id) -- the shed pool for mandatory allocations
        lane_tail: Dict[int, List[Tuple[int, int]]] = {}

        def shed_draft_page() -> bool:
            """Give back the newest draft-tail page of the widest planned
            span: speculation is best-effort, a feedback token is not.
            Plain decode must never fail where it would have succeeded
            without speculation."""
            cand = [(c, i) for i, c in lane_cols.items() if lane_tail.get(i)]
            if not cand:
                return False
            _, i = max(cand)
            j, page = lane_tail[i].pop()
            trunc = self.tables.truncate_to(i, self.tables.n_blocks(i) - 1)
            assert trunc == [page], (trunc, page)
            fresh.remove(page)
            self.allocator.free([page])
            lane_cols[i] = j          # span now ends where that block began
            return True

        for d_idx, i in enumerate(decode_lanes):  # decode lanes first
            s = self._slots[i]
            remaining = s.req.n_new - len(s.out)
            later = len(decode_lanes) - d_idx - 1   # their 1-token floor
            span = 1 if draft_k == 0 else \
                max(1, min(draft_k + 1, remaining, budget - later))
            cols = 0
            for j in range(span):
                if j == 0:
                    # the feedback token is mandatory: preempt prefilling
                    # slots, then shed other lanes' draft tails, or raise
                    while True:
                        try:
                            fresh += self._ensure_block(i, s.pos)
                            break
                        except PagesExhausted:
                            victim = self._youngest_prefilling()
                            if victim is not None:
                                v, pages = self._preempt(victim)
                                preempted.append(v)
                                freed += pages
                            elif not shed_draft_page():
                                raise
                else:
                    try:                  # draft columns are best-effort
                        got = self._ensure_block(i, s.pos + j)
                    except PagesExhausted:
                        break             # degrade the span, keep the lane
                    fresh += got
                    if got:
                        lane_tail.setdefault(i, []).append((j, got[0]))
                cols += 1
            lane_cols[i] = cols
            budget -= cols
        # array fill second: a lane's span may have shrunk after its pass
        # (shed_draft_page), so widths are only final here
        for i in decode_lanes:
            s = self._slots[i]
            cols = lane_cols[i]
            tokens[i, 0] = s.out[-1]
            positions[i, :cols] = np.arange(s.pos, s.pos + cols,
                                            dtype=np.int32)
            if draft_k > 0:
                logit_cols[i] = np.minimum(np.arange(draft_k + 1), cols - 1)
                spec[i] = cols
            sample.append(i)

        for i in self.running_slots():           # then prompt chunks
            s = self._slots[i]
            if not isinstance(s, _Slot) or not s.prefilling:
                continue
            c = min(chunk, s.req.prompt_len - s.pos, max(budget, 0))
            if c <= 0:
                continue                         # idle this step (budget)
            added: List[int] = []                # this slot's new pages only
            try:
                for p in range(s.pos, s.pos + c):
                    added += self._ensure_block(i, p)
            except PagesExhausted:
                if all(not (isinstance(o, _Slot) and o is not s)
                       for o in self._slots):
                    raise                        # alone and cannot grow
                # _preempt frees `added` back to the allocator; keeping the
                # pages out of `fresh` stops the engine scrubbing free-listed
                # (possibly re-allocated) pages
                v, pages = self._preempt(i)
                preempted.append(v)
                freed += pages
                continue
            fresh += added
            tokens[i, :c] = s.req.tokens[s.pos:s.pos + c]
            positions[i, :c] = np.arange(s.pos, s.pos + c, dtype=np.int32)
            chunked[i] = c
            s.pos += c
            budget -= c
            if not s.prefilling:                 # chunk reached prompt end
                logit_cols[i] = c - 1            # 2-D: whole row (one col)
                sample.append(i)
        # re-insert preempted requests youngest-admission first, so the
        # oldest ends up at the queue front: FIFO order survives even a
        # multi-preemption step
        for s in sorted(preempted, key=lambda s: s.seq, reverse=True):
            self._queue.appendleft(s.req)
        return {"tokens": tokens, "positions": positions,
                "slot_map": np.arange(n, dtype=np.int32),
                "logit_cols": logit_cols, "sample": sample,
                "decode": decode_lanes, "spec": spec,
                "chunked": chunked, "fresh": fresh, "freed": freed,
                "requeued": [s.req.rid for s in preempted]}

    def record_first(self, slot: int, token: int) -> bool:
        """Record a chunk-completed slot's first token (sampled from this
        step's logits at the prompt's last position).  The cursor stays at
        ``prompt_len`` -- exactly :meth:`bind`'s contract -- so the next
        step decodes from there.  Returns True when n_new == 1 (done)."""
        s = self.slot(slot)
        assert not s.out and not s.prefilling
        s.out.append(int(token))
        if s.done:
            self._release(slot)
            return True
        return False

    def rollback_speculation(self, slot: int) -> List[int]:
        """Return a lane's over-speculated tail pages to the pool.

        Called by the engine after a verify step's acceptance landed and
        :meth:`record` advanced the cursor: blocks past
        ``pages_needed(pos, page_size)`` backed only rejected draft
        positions, so the table is truncated
        (:meth:`~.paged_kv.BlockTables.truncate_to`) and their pages
        freed.  Post-rollback occupancy is *exactly* what plain decode
        would hold at the same position -- the no-leak invariant the
        speculative property suite pins (tests/test_speculative.py).
        Stale K/V inside kept pages needs no scrub: its positions exceed
        the cursor, so the causal mask rejects it until the stream
        overwrites it in place.  Returns the freed pages."""
        s = self.slot(slot)
        freed = self.tables.truncate_to(
            slot, pages_needed(s.pos, self.page_size))
        if freed:
            self.allocator.free(freed)
        return freed

    def _ensure_block(self, slot: int, pos: int) -> List[int]:
        """Back write position ``pos`` of ``slot`` with a page (may alloc)."""
        if pos // self.page_size >= self.tables.n_blocks(slot):
            page = self.allocator.alloc(1)
            self.tables.append(slot, page)
            return page
        return []

    def _youngest_prefilling(self) -> Optional[int]:
        """Prefilling slot with the least progress (cheapest to restart)."""
        cand = [(self.slot(i).pos, i) for i in self.running_slots()
                if self.slot(i).prefilling]
        return min(cand)[1] if cand else None

    def _preempt(self, slot: int) -> Tuple[_Slot, List[int]]:
        """Preempt a prefilling slot: free its pages, vacate the slot.

        Only legal mid-prefill (no tokens emitted yet), so the restart
        replays the prompt from scratch and the emitted stream is
        unchanged.  The caller re-inserts the request at the queue front in
        admission (seq) order -- everything preempted was admitted before
        anything still queued, so FIFO order is kept.  Returns the slot
        state and the pages freed, so the planner can report free-listed
        pages (the engine must not scrub them under a stale alias)."""
        s = self.slot(slot)
        assert not s.out, "requeue after tokens were emitted would drop them"
        pages = self.tables.release(slot)
        self.allocator.free(pages)
        self._slots[slot] = None
        return s, pages

    def reclaim_out_of_window(self, window: int) -> List[int]:
        """Return pages wholly behind every future attention window.

        For all-sliding-window patterns the next query position of slot
        ``i`` is ``pos``; it (and every later one) attends positions
        ``> pos - window`` only, so logical blocks entirely below
        ``(pos - window + 1)`` are dead.  They go back to the free list at
        the step boundary -- the paged kernel never fetched them anyway
        (its ``first`` re-basing uses the same arithmetic).  Pool occupancy
        becomes O(window) per sequence instead of O(generated length).
        """
        freed: List[int] = []
        for i in self.running_slots():
            s = self.slot(i)
            first_live = max(0, s.pos - window + 1) // self.page_size
            freed += self.tables.free_prefix(i, first_live)
        if freed:
            self.allocator.free(freed)
        return freed

    # -------------------------------------------------------------- decode
    def ensure_pages(self) -> List[int]:
        """Back every running sequence's next write position with a page.

        Returns the newly allocated pages (caller must scrub their ``pos``
        before the decode step).  Raises PagesExhausted if the pool cannot
        grow a running sequence -- admission headroom makes this unreachable
        unless the pool is smaller than one sequence's worst case."""
        fresh: List[int] = []
        for i in self.running_slots():
            s = self.slot(i)
            if s.pos // self.page_size >= self.tables.n_blocks(i):
                page = self.allocator.alloc(1)
                self.tables.append(i, page)
                fresh.extend(page)
        return fresh

    def batch(self) -> Dict[str, np.ndarray]:
        """Fixed-shape decode batch arrays.

        Idle slots carry token 0, an all-trash block-table row, and --
        load-bearing -- ``pos = POS_SENTINEL``: their lanes still execute
        the KV write, and the sentinel both routes it to the trash page
        (block index clips into the all-trash row) and makes the written
        entry unattendable (the causal mask rejects sentinel positions).
        An idle lane must never write a *real* position anywhere, or active
        sequences gathering their own unmapped (trash) blocks would see a
        fake valid KV entry."""
        tokens = np.zeros((self.n_slots, 1), np.int32)
        pos = np.full((self.n_slots,), POS_SENTINEL, np.int32)
        for i in self.running_slots():
            s = self.slot(i)
            tokens[i, 0] = s.out[-1]
            pos[i] = s.pos
        return {"tokens": tokens, "pos": pos,
                "block_tables": self.tables.as_array()}

    def record(self, slot: int, token: int) -> bool:
        """Record one decoded token; returns True (and releases the slot's
        pages) when the request just finished."""
        s = self.slot(slot)
        s.out.append(int(token))
        s.pos += 1
        if s.done:
            self._release(slot)
            return True
        return False

    # ------------------------------------------------------------- release
    def _release(self, slot: int) -> None:
        self.allocator.free(self.tables.release(slot))
        self._slots[slot] = None
        self.n_finished += 1
