"""Overlapped token-budget step loop: the serving back-end.

This is ``ServeEngine``'s chunked step loop extracted into its own
layer, restructured so host and device work overlap.  The closed-loop
original dispatched ``model_step`` for step *t*, then blocked on the
full logits transfer, sampled every lane on the host, and only then
planned step *t+1* -- the device idled through all of it.  The split
loop instead pipelines (docs/serving.md has the diagram):

* **sample on device** -- one jit'd sampler (``sample_step``) draws
  every lane's token(s) from the step's logits in a single device call,
  keeping the per-request rng discipline bit-exact (a lane's key
  advances once per *emitted* token, greedy lanes never advance).  Only
  the (R,)-token vector ever crosses to the host: one transfer per
  step, replacing a full (R, C, V) logits pull plus per-lane host
  sampling.
* **plan value-free** -- ``plan_step`` is one-step-stale tolerant by
  construction (scheduler docstring): control flow depends on token
  counts and positions only, so step *t+1* is planned while step *t*'s
  tokens are still device-resident.  The loop records a ``PENDING``
  placeholder for each token it has not synced yet.
* **feed back on device** -- a decode lane's column-0 input for step
  *t+1* is scattered in from step *t*'s device-resident sample vector
  at dispatch, so the model always sees the *exact* sampled token; the
  placeholder never reaches the model.  Decode feedback stays exact --
  only the host's *view* is stale.
* **retire one step late** -- after dispatching step *t+1*, the host
  syncs step *t*'s token vector (the pipeline's only blocking point),
  backfills its ``PENDING`` output slots, fires stream callbacks in
  token order, and records arrival-relative latency.  Output streams
  are bit-identical to the synchronous loop; tokens simply become
  host-visible one step later.

Speculative decode rides the same class but steps synchronously
(``overlap`` is ignored): acceptance-length control flow needs token
*values*, so each verify step retires immediately -- still through the
batched device sampler, which draws every lane's whole candidate span
and the rng key for every possible acceptance length in one call.

jit-variant boundedness is unchanged: the loop adds no ``model_step``
shapes (2 per run: mixed width + pure-decode width), and the sampler
compiles at most two shapes of its own ((R, 1, V) plain, (R, k+1, V)
verify) regardless of arrival pattern.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import paged_kv
from repro.serve.frontend import FrontEnd
from repro.serve.scheduler import Request, Scheduler
from repro.serve.stats import ServeStats

__all__ = ["StepLoop", "PENDING"]

# placeholder for a sampled-but-not-yet-synced token in host bookkeeping
# (scheduler ``out`` lists and the output streams); never fed to the model
# -- dispatch overrides decode feedback with the device-resident value
PENDING = -1


class StepLoop:
    """One serving session's back-end: drives a :class:`Scheduler` fed by
    a :class:`FrontEnd` until both are drained.

    Built by :meth:`ServeEngine.serve` (and through it by the closed-loop
    ``run()`` wrapper); owns the paged cache value, the per-slot device
    rng/temperature state, and the per-request output streams.
    ``overlap=False`` forces synchronous stepping (retire each step
    before planning the next) -- the bit-parity reference for the
    pipelined path, and automatic under ``spec`` (speculative decode).
    """

    def __init__(self, engine, frontend: FrontEnd, sched: Scheduler, cache,
                 kinds, stats: ServeStats, *, num_pages: int, page_size: int,
                 chunk: int, budget: int, reclaim: Optional[int] = None,
                 spec: Optional[Dict[str, Any]] = None, overlap: bool = True):
        self.eng = engine
        self.fe = frontend
        self.sched = sched
        self.cache = cache
        self.kinds = kinds
        self.stats = stats
        self.num_pages = num_pages
        self.page_size = page_size
        self.chunk = chunk
        self.budget = budget
        self.reclaim = reclaim
        self.spec = spec
        self.overlap = bool(overlap) and spec is None
        n = sched.n_slots
        self.outputs: Dict[int, List[int]] = {}
        # per-slot device sampling state: rng key + temperature, written at
        # admission (a requeued request re-seeds identically -- it emitted
        # nothing, so no rng splits were ever consumed)
        self._keys = jnp.zeros((n, 2), jnp.uint32)
        self._temps = jnp.zeros((n,), jnp.float32)
        self._last_tok = jnp.zeros((n,), jnp.int32)  # last step's samples
        # in-flight retirement record: (device token vector, emit rows)
        self._inflight: Optional[Tuple[Any, List[tuple]]] = None
        self._last_t: Dict[int, float] = {}   # rid -> last host-visible time

    # ------------------------------------------------------------ the loop
    def run(self) -> None:
        """Drain the front-end and scheduler: pump arrivals, step, idle
        between future arrivals.  Ends when no request is scheduled,
        queued, or running."""
        try:
            while True:
                now, released = self.fe.pump(self.sched)
                for req in released:
                    if req.prompt_len + req.n_new > self.eng.max_len:
                        raise ValueError(
                            f"request {req.rid}: {req.prompt_len}+"
                            f"{req.n_new} tokens exceeds "
                            f"max_len={self.eng.max_len}")
                if not self.sched.has_work:
                    if self.fe.n_scheduled == 0:
                        break
                    self._retire()        # flush streams before idling
                    self.fe.wait(now)
                    continue
                self.step(now)
        finally:
            self._retire()

    def step(self, now: float) -> None:
        """One engine step: admit, plan, dispatch, sample, account."""
        eng, sched, stats, spec = self.eng, self.sched, self.stats, self.spec
        k = spec["k"] if spec else 0
        W = max(self.chunk, k + 1) if spec else self.chunk
        if self.reclaim is not None:
            stats.reclaimed_pages += len(
                sched.reclaim_out_of_window(self.reclaim))
        # ---- admission: a request joins when its first chunk fits
        fresh = []
        while (adm := sched.try_admit_chunked(self.chunk)) is not None:
            req, slot, pages = adm
            fresh += pages
            self._admit(req, slot, now)
        if not sched.running_slots():
            raise paged_kv.PagesExhausted(
                "queued request cannot ever be admitted: pool of "
                f"{self.num_pages} pages (page_size={self.page_size}) is "
                "too small for its first chunk + decode headroom")
        t0 = self.fe.now()
        plan = sched.plan_step(self.chunk, self.budget, draft_k=k)
        stats.requeues += len(plan["requeued"])
        # a request admitted above may have been preempted inside this very
        # plan_step: its admission pages are back on the free list (possibly
        # re-allocated -- then they are in plan["fresh"] under the new
        # owner), so drop the stale aliases from the scrub set
        drop = set(plan["freed"])
        fresh = [p for p in fresh if p not in drop]
        # scrub unconditionally: admission pages must be sentinel-clean
        # before any later step writes chunks into them, even if this step
        # is abandoned below.  The draft cache shares the block tables, so
        # it scrubs the same pages.
        self.cache = paged_kv.scrub_pages(self.cache, self.kinds,
                                          fresh + plan["fresh"])
        if spec:
            spec["cache"] = paged_kv.scrub_pages(
                spec["cache"], self.kinds, fresh + plan["fresh"])
        if not plan["sample"] and not plan["chunked"]:
            return                  # every planned slot was preempted
        # pure-decode steps run the (R, 1) column slice -- a full-width
        # step would burn masked lanes per slot once every prompt is in.
        # jit variants stay bounded per (max_slots, chunk, pool shape[,
        # draft_k]): mixed/verify width + pure-decode width, still
        # independent of prompt lengths and arrival pattern.
        spec_lanes = {i: c for i, c in plan["spec"].items() if c > 1}
        w = W if (plan["chunked"] or spec_lanes) else 1
        tokens = plan["tokens"]
        if spec and (plan["chunked"] or plan["spec"]):
            # draft pass: mirrors prompt chunks into the draft cache, feeds
            # every decode lane's feedback token, and proposes each
            # speculating lane's draft tokens, which fill the placeholder
            # verify columns (engine._draft_propose documents the pass)
            drafts = eng._draft_propose(spec, plan, sched, spec_lanes,
                                        W if plan["chunked"] else 2)
            for i, cols in spec_lanes.items():
                tokens[i, 1:cols] = drafts[i][:cols - 1]
        tok_in = jnp.asarray(tokens[:, :w])
        if spec is None and plan["decode"]:
            # decode feedback stays exact: the host's view of these tokens
            # is a PENDING placeholder (plain mode never syncs values into
            # the scheduler, pipelined or not), the device value is
            # authoritative.  Spec mode records real values and skips this.
            rows_d = jnp.asarray(np.asarray(plan["decode"], np.int32))
            tok_in = tok_in.at[rows_d, 0].set(self._last_tok[rows_d])
        logits, self.cache = eng._model_step(
            eng.params, tok_in,
            jnp.asarray(plan["positions"][:, :w]),
            jnp.asarray(plan["slot_map"]), self.cache,
            jnp.asarray(sched.tables.as_array()),
            jnp.asarray(plan["logit_cols"]),
            eng.act_bits, attn_impl=eng.attn_impl)
        stats.chunk_prefill_tokens += sum(plan["chunked"].values())
        # one device call samples every lane's candidate token(s) and the
        # rng key state for every possible acceptance length
        toks, keys_seq = eng._sample_span(logits, self._keys, self._temps)
        if spec:
            emitted_step = self._finish_spec(plan, spec_lanes, tokens,
                                             toks, keys_seq)
        else:
            emitted_step = self._finish_plain(plan, toks, keys_seq)
        dt = self.fe.now() - t0
        # chunk-carrying steps are prefill-side: their time AND their
        # sampled tokens (first tokens plus any decode lanes riding the
        # step) leave the decode rate, so decode_tok_per_s measures the
        # steady-state decode batch -- comparable across modes
        if plan["chunked"]:
            stats.prefill_s += dt
            stats.prefill_tokens += emitted_step
        else:
            stats.decode_s += dt
        stats.steps += 1
        stats.peak_pages = max(stats.peak_pages,
                               self.num_pages - 1 - sched.allocator.n_free)

    # ---------------------------------------------------------- inner steps
    def _admit(self, req: Request, slot: int, now: float) -> None:
        rid = req.rid
        if rid not in self.stats.queue_wait_s:
            arrival = self.fe.arrival_s.get(rid)
            if arrival is not None:
                self.stats.queue_wait_s[rid] = now - arrival
        self.fe.note_admitted(rid)
        self._keys = self._keys.at[slot].set(jax.random.PRNGKey(req.seed))
        self._temps = self._temps.at[slot].set(
            jnp.float32(req.temperature))

    def _finish_plain(self, plan, toks, keys_seq) -> int:
        """Value-free advance for the plain (non-speculative) step: record
        PENDING placeholders, queue the device token vector for
        retirement, retire the previous step's (pipelined) or this one's
        (synchronous)."""
        sched, stats = self.sched, self.stats
        n = sched.n_slots
        m = np.zeros((n,), np.int32)          # rng splits consumed per lane
        rows = []
        for i in plan["sample"]:
            s = sched.slot(i)
            rid = s.req.rid
            m[i] = 1
            out = self.outputs.setdefault(rid, [])
            idx = len(out)
            out.append(PENDING)
            first = not s.out
            if first:
                stats.ttft_steps[rid] = stats.steps + 1
                done = sched.record_first(i, PENDING)
            else:
                done = sched.record(i, PENDING)
            rows.append((i, rid, idx, first, done))
            stats.tokens_out += 1
        self._keys = keys_seq[jnp.arange(n), jnp.asarray(m)]
        tok_dev = toks[:, 0]
        self._last_tok = tok_dev
        pending = (tok_dev, rows)
        if self.overlap:
            prev, self._inflight = self._inflight, pending
            if prev is not None:
                self._retire_record(prev)
        else:
            self._retire_record(pending)
        return len(rows)

    def _finish_spec(self, plan, spec_lanes, tokens, toks, keys_seq) -> int:
        """Synchronous accept/rollback for a speculative verify step: walk
        each lane's candidate span (host control flow needs the values),
        keep the longest draft/sample agreement prefix plus the corrected
        token.  Every emitted token comes from the same logits row + rng
        split plain decode would produce (rejected columns never consume
        rng -- the sampler returned the key state per acceptance length),
        so acceptance changes speed, never output."""
        sched, stats, spec = self.sched, self.stats, self.spec
        n = sched.n_slots
        vals = np.asarray(toks)               # (R, C): one transfer
        now = self.fe.now()
        m = np.zeros((n,), np.int32)
        emitted_step = 0
        for i in plan["sample"]:
            s = sched.slot(i)
            rid = s.req.rid
            out = self.outputs.setdefault(rid, [])
            if not s.out:                     # the request's first token
                tok = int(vals[i, 0])
                m[i] = 1
                out.append(tok)
                stats.tokens_out += 1
                emitted_step += 1
                stats.ttft_steps[rid] = stats.steps + 1
                done = sched.record_first(i, tok)
                self._emit(rid, len(out) - 1, tok, now, True, done)
                continue
            cols = plan["spec"].get(i, 1)
            emitted = []
            for j in range(cols):
                tok = int(vals[i, j])
                emitted.append(tok)
                if j + 1 >= cols or tokens[i, j + 1] != tok:
                    break
            m[i] = len(emitted)
            if cols > 1:
                stats.record_acceptance(rid, cols - 1, len(emitted) - 1)
            done = False
            for tok in emitted:
                out.append(tok)
                stats.tokens_out += 1
                done = sched.record(i, tok)
                self._emit(rid, len(out) - 1, tok, now, False, done)
            emitted_step += len(emitted)
            if done:
                spec["frontier"].pop(i, None)  # slot may be re-admitted
            elif cols > 1:
                # pages past the acceptance point backed only rejected
                # draft positions: return them now; the draft write cursor
                # clamps back too (rejected-token KV is overwritten in
                # place by the stream)
                sched.rollback_speculation(i)
                f = spec["frontier"]
                f[i] = min(f.get(i, s.pos), s.pos)
        if spec_lanes:
            stats.spec_steps += 1
        self._keys = keys_seq[jnp.arange(n), jnp.asarray(m)]
        return emitted_step

    # ----------------------------------------------------------- retirement
    def _retire(self) -> None:
        """Retire the in-flight step, if any (loop exit / idle / error)."""
        prev, self._inflight = self._inflight, None
        if prev is not None:
            self._retire_record(prev)

    def _retire_record(self, pending) -> None:
        """Sync one step's device token vector -- the only blocking
        device->host transfer per step -- and make its tokens
        host-visible: backfill PENDING output slots, fire stream
        callbacks, stamp latency."""
        tok_dev, rows = pending
        vals = np.asarray(tok_dev)
        now = self.fe.now()
        for slot, rid, idx, first, done in rows:
            tok = int(vals[slot])
            self.outputs[rid][idx] = tok
            self._emit(rid, idx, tok, now, first, done)

    def _emit(self, rid: int, idx: int, tok: int, now: float, first: bool,
              done: bool) -> None:
        """One token became host-visible: latency stats + stream callback."""
        stats = self.stats
        arrival = self.fe.arrival_s.get(rid)
        if first:
            if arrival is not None:
                stats.ttft_s[rid] = now - arrival
        else:
            prev_t = self._last_t.get(rid)
            if prev_t is not None:
                stats.itl_s.append(now - prev_t)
        self._last_t[rid] = now
        if done:
            if arrival is not None:
                stats.e2e_s[rid] = now - arrival
            self._last_t.pop(rid, None)
        self.fe.emit(rid, idx, tok)
