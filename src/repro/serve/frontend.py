"""Open-loop serving front-end: arrivals, streaming, SLO shedding.

The front-end is the *open* half of the serving split (docs/serving.md):
requests may arrive while the step loop runs, not just before it.  It
owns everything about a request that exists outside a scheduler slot --

* the **arrival queue**: :meth:`FrontEnd.submit` timestamps a request
  (``at`` schedules a future arrival; the Poisson bench pre-schedules a
  whole trace) and :meth:`FrontEnd.pump` releases everything whose
  arrival time has come into the scheduler's admission queue, in arrival
  order, each step;
* **per-token streaming**: an ``on_token(rid, index, token)`` callback
  registered at submit time fires as each token becomes host-visible, in
  token order (the overlapped back-end syncs a step's tokens one step
  late, so "host-visible" trails "sampled" by one step -- the stream
  order is unchanged);
* **SLO-aware shedding**: with ``queue_slo_s`` set, a request still in
  the admission queue past its deadline is dropped
  (:meth:`~repro.serve.scheduler.Scheduler.drop_queued`) instead of
  serving a first token nobody is waiting for anymore; ``max_queue``
  bounds the backlog at submit time.  Only never-admitted requests are
  shed -- an admitted request owns pages and possibly emitted tokens,
  and tearing a live stream would violate the bit-parity contract for
  everything it batched with.

The clock is injectable (``clock`` / ``sleep``) so arrival-dependent
behaviour is deterministic under test: a virtual clock steps time
forward exactly when the test says so.  Submission is thread-safe --- a
live client may :meth:`submit` from another thread while
:meth:`~repro.serve.step_loop.StepLoop.run` drains the queue.

The front-end never touches device state and never samples: it is pure
host bookkeeping feeding :class:`~repro.serve.scheduler.Scheduler`
(admission) and fed by :class:`~repro.serve.step_loop.StepLoop`
(token retirement).  ``ServeEngine.run()`` is exactly this wiring with
every request submitted up front -- the closed loop is a degenerate
open loop.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.serve.scheduler import Request, Scheduler

__all__ = ["FrontEnd", "as_request"]

OnToken = Callable[[int, int, int], None]     # (rid, index, token)


def as_request(rid: int, r) -> Request:
    """Normalize a submission into a :class:`Request`.

    Accepts a Request (rid is overwritten), a ``{"tokens", "n_new",
    "temperature"?, "seed"?}`` dict, or a ``(tokens, n_new)`` tuple.
    """
    if isinstance(r, Request):
        return dataclasses.replace(r, rid=rid)
    if isinstance(r, dict):
        return Request(rid=rid, tokens=r["tokens"], n_new=r["n_new"],
                       temperature=r.get("temperature", 0.0),
                       seed=r.get("seed", 0))
    tokens, n_new = r
    return Request(rid=rid, tokens=tokens, n_new=n_new)


class FrontEnd:
    """Arrival queue + stream registry for one open-loop serving session.

    clock/sleep: time source and idle wait (defaults
    ``time.monotonic`` / ``time.sleep``); tests inject a virtual pair.
    queue_slo_s: drop a request still unadmitted this long after
    arrival (None: never shed).  max_queue: reject submissions while
    this many requests are waiting (scheduled + queued, unadmitted).
    """

    def __init__(self, *, clock: Optional[Callable[[], float]] = None,
                 sleep: Optional[Callable[[float], None]] = None,
                 queue_slo_s: Optional[float] = None,
                 max_queue: Optional[int] = None):
        self._clock = clock if clock is not None else time.monotonic
        self._sleep = sleep if sleep is not None else time.sleep
        self.queue_slo_s = queue_slo_s
        self.max_queue = max_queue
        self._lock = threading.Lock()
        # (arrival time, submit seq, request) -- seq keeps same-instant
        # arrivals in submit order, so closed-loop admission FIFO (and
        # with it slot assignment, and with it bit-parity) is preserved
        self._arrivals: List[Any] = []
        self._seq = 0
        self._next_rid = 0
        self._on_token: Dict[int, OnToken] = {}
        self._waiting: Dict[int, Request] = {}   # released, not yet admitted
        self.arrival_s: Dict[int, float] = {}
        self.shed: List[int] = []
        self.n_submitted = 0

    # -------------------------------------------------------------- clients
    def now(self) -> float:
        return self._clock()

    def submit(self, r, *, at: Optional[float] = None,
               on_token: Optional[OnToken] = None) -> Request:
        """Register one request, arriving now (default) or at ``at``.

        Returns the normalized :class:`Request` (its ``rid`` identifies
        the stream everywhere: outputs, stats, callbacks).  A request a
        full ``max_queue`` backlog rejects is recorded in :attr:`shed`
        immediately and never reaches the scheduler.
        """
        with self._lock:
            req = r if isinstance(r, Request) and r.rid == self._next_rid \
                else as_request(self._next_rid, r)
            self._next_rid += 1
            self.n_submitted += 1
            t = self._clock() if at is None else float(at)
            self.arrival_s[req.rid] = t
            backlog = len(self._arrivals) + len(self._waiting)
            if self.max_queue is not None and backlog >= self.max_queue:
                self.shed.append(req.rid)
                return req
            if on_token is not None:
                self._on_token[req.rid] = on_token
            heapq.heappush(self._arrivals, (t, self._seq, req))
            self._seq += 1
        return req

    # ------------------------------------------------------------ step loop
    @property
    def n_scheduled(self) -> int:
        """Submitted arrivals not yet released to the scheduler."""
        with self._lock:
            return len(self._arrivals)

    def next_arrival(self) -> Optional[float]:
        with self._lock:
            return self._arrivals[0][0] if self._arrivals else None

    def pump(self, sched: Scheduler):
        """Release due arrivals into the scheduler; shed overdue waiters.

        Called by the step loop once per iteration (and while idling
        between arrivals).  Returns ``(now, released)``: the current
        clock reading -- the step's one timestamp for every latency
        measurement -- and the requests released this call (the loop
        validates them against engine limits before they can admit).
        """
        now = self._clock()
        released: List[Request] = []
        with self._lock:
            while self._arrivals and self._arrivals[0][0] <= now:
                _, _, req = heapq.heappop(self._arrivals)
                sched.submit(req)
                self._waiting[req.rid] = req
                released.append(req)
        if self.queue_slo_s is not None:
            overdue = [rid for rid, req in self._waiting.items()
                       if now - self.arrival_s[rid] > self.queue_slo_s]
            for rid in overdue:
                if sched.drop_queued(rid):
                    del self._waiting[rid]
                    self._on_token.pop(rid, None)
                    self.shed.append(rid)
        return now, released

    def note_admitted(self, rid: int) -> None:
        """A waiter reached a slot: it is no longer sheddable.  (A later
        preemption requeues it inside the scheduler only -- it stays
        off the shed candidate list, by design: its service started.)"""
        self._waiting.pop(rid, None)

    def emit(self, rid: int, index: int, token: int) -> None:
        """Fire the stream callback for one host-visible token."""
        cb = self._on_token.get(rid)
        if cb is not None:
            cb(rid, index, token)

    def wait(self, now: float, cap: float = 0.01) -> None:
        """Idle until the next scheduled arrival (bounded naps, so live
        submissions from other threads are noticed promptly)."""
        nxt = self.next_arrival()
        dt = cap if nxt is None else max(min(nxt - now, cap), 0.0)
        if dt > 0:
            self._sleep(dt)
