"""Flat (non-hierarchical) DDPG baselines.

* granularity="layer": one (wbits, abits) action per layer -- the HAQ-style
  layer-level search the paper compares against (X-L rows).
* granularity="channel": one action per channel group without goals -- the
  "traditional DDPG-based AutoQB" of Fig. 8, showing why the huge flat
  channel-level space needs the hierarchy.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.agent import EpisodeLog
from repro.core.ddpg import DDPG, DDPGConfig, ReplayBuffer
from repro.core.env import QuantEnv, StepCtx
from repro.quant.policy import QuantPolicy


class FlatAgent:
    def __init__(self, env: QuantEnv, seed: int = 0, gamma: float = 0.95,
                 granularity: str = "channel", max_bits: float = 8.0,
                 updates_per_episode=None):
        import jax
        assert granularity in ("layer", "channel")
        self.env = env
        self.granularity = granularity
        self.max_bits = max_bits
        sd = env.state_dim
        adim = 2 if granularity == "layer" else 1
        self.ddpg = DDPG(DDPGConfig(state_dim=sd, action_dim=adim,
                                    gamma=gamma, action_scale=max_bits),
                         jax.random.PRNGKey(seed))
        self.buf = ReplayBuffer(sd, adim)
        self.rng = np.random.default_rng(seed)
        self.updates_per_episode = updates_per_episode

    def run_episode(self, noise: float, train: bool = True
                    ) -> Tuple[EpisodeLog, QuantPolicy]:
        env = self.env
        graph = env.graph
        if env.bounder is not None:
            env.bounder.reset()
        ctx = StepCtx()
        policy = QuantPolicy(mode=env.mode, weight_bits={}, act_bits={})
        transitions = []

        for t, layer in enumerate(graph.layers):
            if self.granularity == "layer":
                s = env.make_state(t, layer, 0, ctx, is_act_step=True)
                a = self.ddpg.act(s, noise, self.rng)
                a = np.clip(np.round(a), 0, self.max_bits)
                if env.bounder is not None:
                    gw, ga = env.bounder.bound_pair(t, float(a[0]),
                                                    float(a[1]))
                    a = np.round([gw, ga])
                wbits = np.full(layer.n_groups, float(a[0]), np.float32)
                aa = float(a[1])
                transitions.append([s, a.astype(np.float32), 0.0, s, 0.0])
            else:
                s = env.make_state(t, layer, 0, ctx, is_act_step=True)
                aa = float(np.clip(np.round(
                    self.ddpg.act(s, noise, self.rng)[0]), 0, self.max_bits))
                transitions.append([s, np.array([aa], np.float32), 0.0, s,
                                    0.0])
                wbits = np.zeros(layer.n_groups, np.float32)
                for gi in range(layer.n_groups):
                    si = env.make_state(t, layer, gi, ctx, is_act_step=False)
                    aw = float(np.clip(np.round(
                        self.ddpg.act(si, noise, self.rng)[0]), 0,
                        self.max_bits))
                    wbits[gi] = aw
                    ctx.aw_prev = aw
                    transitions.append([si, np.array([aw], np.float32), 0.0,
                                        si, 0.0])
                wbits = env.apply_var_ordering(layer, wbits)
            ctx.aa_prev = aa
            policy.weight_bits[layer.name] = wbits
            policy.act_bits[layer.name] = aa
            env.account_rdc(layer, ctx, wbits, aa)

        acc, R, summary = env.episode_reward(policy)
        transitions[-1][2] = R
        transitions[-1][4] = 1.0
        for j in range(len(transitions) - 1):
            transitions[j][3] = transitions[j + 1][0]
        for s, a, r, s2, d in transitions:
            self.buf.push(s, a, r, s2, d)
        if train and len(self.buf) >= 64:
            n = self.updates_per_episode or max(8, len(graph.layers))
            for _ in range(n):
                self.ddpg.update(self.buf.sample(self.rng, 64))
        return EpisodeLog(reward=R, acc=acc,
                          avg_wbits=summary["avg_wbits"],
                          avg_abits=summary["avg_abits"],
                          logic_ratio=summary["logic_ratio"]), policy
