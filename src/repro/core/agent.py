"""Hierarchical DRL agent (HIRO-style HLC + LLC) for kernel-wise quantization.

* HLC: one decision per layer -- a 2-d goal (gw_t, ga_t) = average weight /
  activation QBN for the layer, optionally clamped by Algorithm 1.
* LLC: goal-conditioned; one activation action per layer then one weight
  action per output-channel group, each an integer in [0, 32] (0 = prune).
* Intrinsic reward (section 3.3): r_i = zeta * (-|goal - realized mean|) +
  (1 - zeta) * R_i, deviation assigned at layer completion (normalized per
  group so reward scales are architecture-independent).
* HLC off-policy correction: transitions are re-labeled with a goal chosen
  among {g_t, G_t, 8 Gaussian samples around G_t}; the paper selects the
  *minimal* candidate ("min", default); "ml" implements the original HIRO
  max-likelihood selection under the current LLC (ablation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.ddpg import ACTION_SCALE, DDPG, DDPGConfig, ReplayBuffer
from repro.core.env import QuantEnv, StepCtx
from repro.quant.policy import QuantMode, QuantPolicy


@dataclasses.dataclass
class EpisodeLog:
    reward: float
    acc: float
    avg_wbits: float
    avg_abits: float
    logic_ratio: float


class HierarchicalAgent:
    def __init__(self, env: QuantEnv, seed: int = 0, zeta: float = 0.5,
                 relabel: str = "min", gamma: float = 0.95,
                 updates_per_episode: Optional[int] = None,
                 max_bits: float = 8.0):
        """max_bits: upper clamp of emitted goals/actions.  The paper's space
        is [0, 32]; for quantization searches it converges in [0, 8] and the
        clamp only speeds exploration (set 32.0 for the unrestricted space).
        """
        import jax
        self.env = env
        self.zeta = zeta
        self.relabel = relabel
        self.max_bits = max_bits
        sd = env.state_dim
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        self.hlc = DDPG(DDPGConfig(state_dim=sd, action_dim=2, gamma=gamma,
                                   action_scale=max_bits), k1)
        self.llc = DDPG(DDPGConfig(state_dim=sd + 2, action_dim=1,
                                   gamma=gamma, action_scale=max_bits), k2)
        self.hlc_buf = ReplayBuffer(sd, 2)
        self.llc_buf = ReplayBuffer(sd + 2, 1)
        self.rng = np.random.default_rng(seed)
        self.updates_per_episode = updates_per_episode

    # ------------------------------------------------------------ one episode
    def run_episode(self, noise: float, train: bool = True
                    ) -> Tuple[EpisodeLog, QuantPolicy]:
        env = self.env
        graph = env.graph
        if env.bounder is not None:
            env.bounder.reset()
        ctx = StepCtx()
        policy = QuantPolicy(mode=env.mode, weight_bits={}, act_bits={})

        hlc_transitions = []   # (s, g, [llc states], [llc actions], s_next)
        llc_transitions = []   # (s+g, a, r_placeholder_idx, s2+g, done)

        for t, layer in enumerate(graph.layers):
            s_t = env.make_state(t, layer, 0, ctx, is_act_step=True)
            g = self.hlc.act(s_t, noise, self.rng)            # (gw, ga)
            g = np.clip(g, 0.0, self.max_bits)
            if env.bounder is not None:
                gw, ga = env.bounder.bound_pair(t, float(g[0]), float(g[1]))
                g = np.array([gw, ga], np.float32)
            ctx.gw, ctx.ga = float(g[0]), float(g[1])

            # --- activation action (one per layer) ---
            sa = env.make_state(t, layer, 0, ctx, is_act_step=True)
            sga = np.concatenate([sa, g / ACTION_SCALE])
            aa = self.llc.act(sga, noise, self.rng)[0]
            aa = float(np.clip(np.round(aa), 0, self.max_bits))
            ctx.aa_prev = aa

            # --- weight actions (one per output-channel group) ---
            states, actions = [sga], [aa]
            raw = np.zeros(layer.n_groups, np.float32)
            for gi in range(layer.n_groups):
                s_i = env.make_state(t, layer, gi, ctx, is_act_step=False)
                sgi = np.concatenate([s_i, g / ACTION_SCALE])
                aw = self.llc.act(sgi, noise, self.rng)[0]
                aw = float(np.clip(np.round(aw), 0, self.max_bits))
                ctx.aw_prev = aw
                raw[gi] = aw
                states.append(sgi)
                actions.append(aw)
            wbits = env.apply_var_ordering(layer, raw)
            policy.weight_bits[layer.name] = wbits
            policy.act_bits[layer.name] = aa
            env.account_rdc(layer, ctx, wbits, aa)

            # LLC transitions for this layer; deviation reward at layer end.
            dev_w = abs(float(g[0]) - float(np.mean(wbits)))
            dev_a = abs(float(g[1]) - aa)
            intrinsic = -self.zeta * (dev_w + dev_a) / 2.0
            for j in range(len(states)):
                s2 = states[j + 1] if j + 1 < len(states) else states[j]
                r = intrinsic if j == len(states) - 1 else 0.0
                llc_transitions.append(
                    [states[j], np.array([actions[j]], np.float32), r, s2,
                     0.0])
            hlc_transitions.append([s_t, g.copy(), states, actions])

        # --- extrinsic reward at episode end ---
        acc, R, summary = env.episode_reward(policy)
        llc_transitions[-1][2] += (1.0 - self.zeta) * R
        llc_transitions[-1][4] = 1.0
        for j, (s, a, r, s2, d) in enumerate(llc_transitions):
            self.llc_buf.push(s, a, r, s2, d)

        for t, (s_t, g, states, actions) in enumerate(hlc_transitions):
            r = R if t == len(hlc_transitions) - 1 else 0.0
            s_next = hlc_transitions[t + 1][0] \
                if t + 1 < len(hlc_transitions) else s_t
            done = 1.0 if t == len(hlc_transitions) - 1 else 0.0
            g_used = self._relabel(g, states, actions)
            self.hlc_buf.push(s_t, g_used, r, s_next, done)

        if train:
            self._train()
        return EpisodeLog(reward=R, acc=acc,
                          avg_wbits=summary["avg_wbits"],
                          avg_abits=summary["avg_abits"],
                          logic_ratio=summary["logic_ratio"]), policy

    # ------------------------------------------------------------- relabeling
    def _relabel(self, g: np.ndarray, states: List[np.ndarray],
                 actions: List[float]) -> np.ndarray:
        """Goal re-labeling for off-policy HLC training (section 3.2)."""
        aw = np.asarray(actions[1:], np.float32)
        G = np.array([aw.mean() if len(aw) else actions[0], actions[0]],
                     np.float32)
        cands = [g, G] + [np.clip(G + self.rng.normal(0, 1.0, 2), 0,
                                  self.max_bits) for _ in range(8)]
        if self.relabel == "min":
            # Paper: "selects the minimal goal to re-label the experience".
            stack = np.stack(cands)
            return stack[np.argmin(stack.sum(axis=1))]
        # "ml": HIRO max-likelihood -- candidate minimizing sum_i
        # ||a_i - mu_lo(s_i, g~)||^2 under the current LLC.
        import jax.numpy as jnp
        from repro.core.ddpg import mlp_apply, _sigmoid_scale
        base = np.stack([s[:-2] for s in states])              # strip goal dims
        acts = np.asarray(actions, np.float32)[:, None]
        errs = []
        for cand in cands:
            sg = np.concatenate(
                [base, np.tile(cand / ACTION_SCALE, (len(base), 1))], axis=1)
            mu = np.asarray(mlp_apply(self.llc.state["actor"],
                                      jnp.asarray(sg),
                                      final_act=_sigmoid_scale))
            errs.append(float(((mu - acts) ** 2).sum()))
        return cands[int(np.argmin(errs))]

    # ---------------------------------------------------------------- training
    def _train(self):
        n = self.updates_per_episode or max(8, len(self.env.graph.layers))
        if len(self.llc_buf) >= 64:
            for _ in range(n):
                self.llc.update(self.llc_buf.sample(self.rng, 64))
        if len(self.hlc_buf) >= 64:
            for _ in range(max(4, n // 4)):
                self.hlc.update(self.hlc_buf.sample(self.rng, 64))
