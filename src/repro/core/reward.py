"""Extrinsic rewards: NetScore (Eq. 2), FLOP-based baseline, and the
TPU-roofline-informed variant.

NetScore: Omega(N) = 20 * log10( a(N)^alpha / (p(N)^beta * m(N)^gamma) ).
We use normalized ingredients (a in (0, 100]; p = avg weight bits / 32;
m = logic ops / full-precision logic ops), which is a monotone reparametrization
of the paper's absolute counts and keeps Omega architecture-comparable.

Search protocols (section 3.3):
* resource-constrained: alpha=1, beta=0, gamma=0 -- pure accuracy; the bit
  budget is enforced by Algorithm 1 action-space limiting (core/bound.py).
* accuracy-guaranteed:  alpha=2, beta=0.5, gamma=0.5 -- rewards shrinking
  p and m; accuracy enters squared.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.quant.policy import QuantPolicy, QuantizableGraph


@dataclasses.dataclass(frozen=True)
class RewardCfg:
    alpha: float
    beta: float
    gamma: float
    kind: str = "netscore"           # netscore | flop | roofline

    @staticmethod
    def resource_constrained() -> "RewardCfg":
        return RewardCfg(alpha=1.0, beta=0.0, gamma=0.0)

    @staticmethod
    def accuracy_guaranteed() -> "RewardCfg":
        return RewardCfg(alpha=2.0, beta=0.5, gamma=0.5)

    @staticmethod
    def flop_based() -> "RewardCfg":
        """Section 4.3 baseline [AMC-style]: only the logic-op term."""
        return RewardCfg(alpha=2.0, beta=0.0, gamma=1.0, kind="flop")


def netscore(acc_pct: float, p: float, m: float, cfg: RewardCfg) -> float:
    """acc_pct in (0, 100]; p, m normalized to (0, 1]."""
    a = max(acc_pct, 1e-3)
    # physical floors: p >= 1/32 (1-bit weights), m >= 1/1024 (1x1-bit MACs);
    # without them a degenerate all-pruned policy games the log terms.
    p = max(p, 1.0 / 32.0)
    m = max(m, 1.0 / 1024.0)
    return 20.0 * math.log10(a ** cfg.alpha / (p ** cfg.beta * m ** cfg.gamma))


def extrinsic_reward(acc_pct: float, graph: QuantizableGraph,
                     policy: QuantPolicy, cfg: RewardCfg,
                     roofline: Optional["TPURoofline"] = None) -> float:
    p = policy.avg_weight_bits(graph) / 32.0
    m = policy.logic_ops(graph) / max(graph.total_macs * 32.0 * 32.0, 1.0)
    if cfg.kind == "flop":
        # FLOP-based reward ignores the weight-count term entirely.
        return netscore(acc_pct, 1.0, m, cfg)
    if cfg.kind == "roofline" and roofline is not None:
        # Replace m with the roofline latency estimate (normalized to the
        # full-precision model) so beta/gamma trade memory vs compute
        # bottlenecks of the actual target device.
        lat = roofline.latency(graph, policy) / roofline.latency_full(graph)
        return netscore(acc_pct, p, lat, cfg)
    return netscore(acc_pct, p, m, cfg)


def reward_summary(acc_pct: float, graph: QuantizableGraph,
                   policy: QuantPolicy, cfg: RewardCfg) -> Dict[str, float]:
    return {
        "acc_pct": acc_pct,
        "avg_wbits": policy.avg_weight_bits(graph),
        "avg_abits": policy.avg_act_bits(graph),
        "logic_ratio": policy.logic_ops(graph) /
        max(graph.total_macs * 32.0 * 32.0, 1.0),
        "reward": extrinsic_reward(acc_pct, graph, policy, cfg),
    }
