"""AutoQ core: hierarchical DRL search for kernel-wise quantization.

The paper's contribution as a composable library:
  env.QuantEnv        -- model-agnostic quantization MDP (Eq. 1 states)
  agent.HierarchicalAgent -- HLC+LLC DDPG with HIRO goal relabeling
  flat.FlatAgent      -- layer-level (HAQ-like) / flat-channel baselines
  reward              -- NetScore / FLOP / roofline extrinsic rewards
  bound.LayerBounder  -- Algorithm 1 resource-constrained action limiting
  search.run_search   -- explore/exploit episode schedule
  evaluate            -- jitted QuantPolicy -> accuracy evaluators
  roofline.TPURoofline -- TPU v5e latency/energy estimates per policy
"""
from repro.core.agent import HierarchicalAgent
from repro.core.bound import LayerBounder
from repro.core.ddpg import DDPG, DDPGConfig, ReplayBuffer
from repro.core.env import QuantEnv
from repro.core.evaluate import make_cnn_evaluator, make_lm_evaluator
from repro.core.flat import FlatAgent
from repro.core.reward import RewardCfg, extrinsic_reward, netscore
from repro.core.roofline import TPURoofline
from repro.core.search import SearchResult, run_search

__all__ = [
    "HierarchicalAgent", "LayerBounder", "DDPG", "DDPGConfig", "ReplayBuffer",
    "QuantEnv", "make_cnn_evaluator", "make_lm_evaluator", "FlatAgent",
    "RewardCfg", "extrinsic_reward", "netscore", "TPURoofline",
    "SearchResult", "run_search",
]
