"""The kernel-wise quantization environment.

Wraps a model (via its QuantizableGraph + a jitted evaluator) as the MDP the
hierarchical agent explores: states are the paper's Eq. 1 feature vectors,
one decision step per activation layer + per weight output-channel group,
and the extrinsic reward is NetScore on the quantized model's validation
accuracy (evaluated without fine-tuning, as the paper prescribes).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.bound import LayerBounder
from repro.core.reward import RewardCfg, extrinsic_reward, reward_summary
from repro.core.roofline import TPURoofline
from repro.quant.policy import (LayerInfo, QuantMode, QuantPolicy,
                                QuantizableGraph)

STATE_DIM = 17


def _get_path(tree, path):
    node = tree
    for key in path:
        node = node[key]
    return node


def group_weight_vars(graph: QuantizableGraph, params) -> Dict[str, np.ndarray]:
    """Per-channel-group weight variance (the wvar_i state feature, also used
    by the variance-ordering action constraint)."""
    out = {}
    for layer in graph.layers:
        w = np.asarray(_get_path(params, layer.param_path), np.float32)
        axis = layer.channel_axis % w.ndim
        w = np.moveaxis(w, axis, -1).reshape(-1, w.shape[axis])
        var = w.var(axis=0)                                   # (c_out,)
        gsz = layer.group_size
        pad = (-len(var)) % gsz
        if pad:
            var = np.pad(var, (0, pad), mode="edge")
        gv = var.reshape(-1, gsz).mean(axis=1)[: layer.n_groups]
        out[layer.name] = gv
    return out


@dataclasses.dataclass
class StepCtx:
    """Mutable episode context for building Eq. 1 states."""
    rdc: float = 0.0             # reduced logic ops so far
    gw: float = 32.0
    ga: float = 32.0
    aw_prev: float = 32.0
    aa_prev: float = 32.0


class QuantEnv:
    def __init__(self, graph: QuantizableGraph, params,
                 evaluator: Callable[[QuantPolicy], float],
                 reward_cfg: RewardCfg,
                 mode: QuantMode = QuantMode.QUANT,
                 roofline: Optional[TPURoofline] = None,
                 bounder: Optional[LayerBounder] = None):
        self.graph = graph
        self.evaluator = evaluator
        self.reward_cfg = reward_cfg
        self.mode = mode
        self.roofline = roofline
        self.bounder = bounder
        self.group_vars = group_weight_vars(graph, params)
        self._logic_full = graph.total_macs * 32.0 * 32.0
        self._cmax = float(max(max(l.c_in, l.c_out) for l in graph.layers))
        self._logic_max = float(max(l.macs for l in graph.layers))
        g_idx = 0
        self._global_idx = {}
        for layer in graph.layers:
            self._global_idx[layer.name] = g_idx
            g_idx += layer.n_groups
        self._total_groups = g_idx

    @property
    def state_dim(self) -> int:
        return STATE_DIM

    @property
    def n_layers(self) -> int:
        return len(self.graph.layers)

    def make_state(self, t: int, layer: LayerInfo, group_idx: int,
                   ctx: StepCtx, is_act_step: bool) -> np.ndarray:
        """Eq. 1 state vector, normalized to O(1) ranges."""
        gi = self._global_idx[layer.name] + min(group_idx, layer.n_groups - 1)
        rst = sum(l.macs for l in self.graph.layers[t:]) * 32.0 * 32.0
        wvar = self.group_vars[layer.name]
        wv = wvar[min(group_idx, layer.n_groups - 1)] / (wvar.max() + 1e-9)
        return np.array([
            gi / max(self._total_groups, 1),                  # i
            t / max(self.n_layers, 1),                        # t
            layer.c_in / self._cmax,                          # c_in
            layer.c_out / self._cmax,                         # c_out
            1.0,                                              # w (fmap, 1 for LM)
            1.0,                                              # h
            layer.stride / 2.0,                               # str
            layer.k / 7.0,                                    # k
            layer.macs / self._logic_max,                     # logic_t
            ctx.rdc / self._logic_full,                       # rdc
            rst / self._logic_full,                           # rst
            ctx.gw / 32.0,                                    # gw_t
            ctx.ga / 32.0,                                    # ga_t
            ctx.aw_prev / 32.0,                               # aw_{i-1}
            ctx.aa_prev / 32.0,                               # aa_i
            wv,                                               # wvar_i
            1.0 if is_act_step else 0.0,                      # step kind
        ], np.float32)

    def apply_var_ordering(self, layer: LayerInfo,
                           actions: np.ndarray) -> np.ndarray:
        """Project actions onto the paper's constraint: for any two channels,
        (aw_x/aw_y - 1)(wvar_x/wvar_y - 1) > 0 -- i.e. bit-width order follows
        weight-variance order.  Implemented as sorting the action multiset by
        the variance ranking."""
        var = self.group_vars[layer.name]
        order = np.argsort(var)                 # low variance first
        sorted_actions = np.sort(actions)       # low bits first
        out = np.empty_like(actions)
        out[order] = sorted_actions
        return out

    def account_rdc(self, layer: LayerInfo, ctx: StepCtx, wbits: np.ndarray,
                    abits: float):
        full = layer.macs * 32.0 * 32.0
        used = layer.macs * float(np.mean(wbits)) * abits
        ctx.rdc += full - used

    def episode_reward(self, policy: QuantPolicy):
        acc = float(self.evaluator(policy))
        r = extrinsic_reward(acc, self.graph, policy, self.reward_cfg,
                             roofline=self.roofline)
        summary = reward_summary(acc, self.graph, policy, self.reward_cfg)
        return acc, r, summary
