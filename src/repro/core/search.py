"""Search driver: explore / exploit episode schedule (paper section 4).

AutoQ first explores `n_explore` episodes with constant Gaussian noise
delta=0.5, then exploits `n_exploit` episodes with exponentially decayed
noise, tracking the best policy by extrinsic reward.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional

from repro.core.agent import EpisodeLog
from repro.quant.policy import QuantPolicy


@dataclasses.dataclass
class SearchResult:
    best_policy: Optional[QuantPolicy]
    best_log: Optional[EpisodeLog]
    history: List[EpisodeLog]
    wall_s: float

    def reward_curve(self):
        return [h.reward for h in self.history]

    def acc_curve(self):
        return [h.acc for h in self.history]


def run_search(agent, n_explore: int = 100, n_exploit: int = 300,
               noise0: float = 0.5, decay: float = 0.99,
               callback: Optional[Callable[[int, EpisodeLog], None]] = None,
               select: str = "reward") -> SearchResult:
    """agent: HierarchicalAgent or FlatAgent (both expose run_episode)."""
    t0 = time.time()
    history: List[EpisodeLog] = []
    best_log, best_policy = None, None
    noise = noise0
    for ep in range(n_explore + n_exploit):
        if ep >= n_explore:
            noise *= decay
        log, policy = agent.run_episode(noise=noise)
        history.append(log)
        key = log.reward if select == "reward" else log.acc
        best_key = None if best_log is None else (
            best_log.reward if select == "reward" else best_log.acc)
        if best_log is None or key > best_key:
            best_log, best_policy = log, policy.copy()
        if callback is not None:
            callback(ep, log)
    return SearchResult(best_policy=best_policy, best_log=best_log,
                        history=history, wall_s=time.time() - t0)
