"""Algorithm 1: resource-constrained goal bounding (action-space limiting).

In resource-constrained searches the reward carries no incentive to shrink
bit-widths (alpha=1, beta=gamma=0), so the budget is enforced structurally:
the HLC may emit any goal for early layers, but once the remaining budget
could not be met even if every following layer used the minimum goal, the
goal is clamped.

Fidelity note: the paper's printed line 16, g_t = min(g_t, (1 -
logic_duty/logic_t) * 32), clamps *harder* when more budget remains, which
contradicts the surrounding text ("bound g_t if it is too large to meet
BBN-bar").  We implement the evident intent: layer t may spend at most
logic_duty, so g_t <= (logic_duty / logic_t) * 32 (per-goal fraction).  The
budget itself (line 5) is quadratic in the two goal fractions, so each goal
is bounded assuming its partner takes the target average.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.quant.policy import QuantizableGraph


@dataclasses.dataclass
class LayerBounder:
    """Tracks the logic-op budget across one episode (weights x activations).

    budget = sum_l logic_l * (bits_w/32) * (bits_a/32)        (Alg. 1 line 5)
    Layer t with goals (gw, ga) consumes (gw/32)(ga/32) logic_t (line 18,
    extended to the two-goal form the HLC actually emits).
    """
    graph: QuantizableGraph
    avg_bits_w: float            # target network-average weight bits
    avg_bits_a: float            # target network-average activation bits
    g_min: float = 1.0

    def __post_init__(self):
        self.logic = [l.macs for l in self.graph.layers]
        self.budget = sum(self.logic) * (self.avg_bits_w / 32.0) * \
            (self.avg_bits_a / 32.0)
        self.current = 0.0

    def reset(self):
        self.current = 0.0

    def _duty(self, t: int) -> float:
        """Logic ops layer t may still spend, leaving g_min feasible later."""
        logic_rest = sum(self.logic[t + 1:])
        return self.budget - (self.g_min / 32.0) ** 2 * logic_rest \
            - self.current

    def bound_pair(self, t: int, gw: float, ga: float) -> Tuple[float, float]:
        """Clamp the HLC's (weight, activation) goals for layer t.

        gw is bounded assuming the activation goal sits at the target
        average; ga is then bounded *exactly* against the remaining duty
        given the chosen gw, so the layer's consumed logic never exceeds
        its duty (up to the g_min floor)."""
        gw = max(gw, self.g_min)
        ga = max(ga, self.g_min)
        duty = max(self._duty(t), 0.0)
        lt = self.logic[t]
        if lt > 0:
            cap_w = duty / lt * 32.0 / max(self.avg_bits_a / 32.0, 1e-6)
            gw = min(gw, max(self.g_min, cap_w))
            cap_a = duty * 32.0 * 32.0 / (lt * max(gw, 1e-6))
            ga = min(ga, max(self.g_min, cap_a))
        self.current += (gw / 32.0) * (ga / 32.0) * lt
        return gw, ga
