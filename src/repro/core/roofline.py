"""Lightweight TPU roofline model (paper section 3: "AutoQB adopts a
lightweight Roofline model to take the latency and energy of a specific
hardware platform into consideration").

The paper fits linear latency/energy models for an FPGA; here the target is
TPU v5e, so the model maps a quantization policy to {MXU time, HBM time} per
layer and takes the roofline max.  Bit-width buckets reflect what a TPU can
actually exploit (DESIGN.md section 3): storage packs to int4/int8/bf16; MXU
rate doubles at int8 but does not improve further below 8 bits.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.quant.policy import QuantPolicy, QuantizableGraph

# TPU v5e per-chip constants (assignment-provided).
PEAK_BF16 = 197e12          # FLOP/s
PEAK_INT8 = 394e12          # OP/s (2x bf16)
HBM_BW = 819e9              # B/s
ICI_BW = 50e9               # B/s per link
ENERGY_PJ_PER_MAC_BF16 = 1.3
ENERGY_PJ_PER_MAC_INT8 = 0.4
ENERGY_PJ_PER_BYTE_HBM = 15.0


def storage_bytes_per_elem(bits: np.ndarray) -> np.ndarray:
    """Packed storage bucket: <=4 -> int4 (0.5 B), <=8 -> int8, else bf16."""
    return np.where(bits <= 0.5, 0.0,
                    np.where(bits <= 4, 0.5,
                             np.where(bits <= 8, 1.0, 2.0)))


def mxu_rate(bits: np.ndarray) -> np.ndarray:
    """Effective MXU rate for a channel quantized at `bits`."""
    return np.where(bits <= 8, PEAK_INT8, PEAK_BF16)


@dataclasses.dataclass(frozen=True)
class TPURoofline:
    chips: int = 1
    act_bytes: float = 2.0       # activations stay bf16 unless quantized <=8

    def _layer_terms(self, layer, wbits: np.ndarray, abits: float):
        frac_alive = float(np.mean(wbits > 0.5))
        macs = layer.macs * frac_alive / self.chips
        rate = float(np.mean(mxu_rate(np.maximum(wbits, 1e-3))))
        if abits > 8:             # both operands must be <=8 for int8 MXU
            rate = PEAK_BF16
        t_compute = 2.0 * macs / rate
        w_bytes = float(np.mean(storage_bytes_per_elem(wbits))) * layer.numel \
            / self.chips
        a_bytes = (1.0 if abits <= 8 else 2.0) * \
            (layer.macs / max(layer.c_out, 1)) / self.chips  # input reuse proxy
        t_mem = (w_bytes + a_bytes) / HBM_BW
        return t_compute, t_mem, macs, w_bytes + a_bytes

    def latency(self, graph: QuantizableGraph, policy: QuantPolicy) -> float:
        total = 0.0
        for layer in graph.layers:
            wb = policy.expand_weight_bits(layer)
            tc, tm, _, _ = self._layer_terms(layer, wb, policy.act_bits[layer.name])
            total += max(tc, tm)
        return total

    def latency_full(self, graph: QuantizableGraph) -> float:
        total = 0.0
        for layer in graph.layers:
            wb = np.full(layer.c_out, 16.0)
            tc, tm, _, _ = self._layer_terms(layer, wb, 16.0)
            total += max(tc, tm)
        return total

    def energy(self, graph: QuantizableGraph, policy: QuantPolicy) -> float:
        total = 0.0
        for layer in graph.layers:
            wb = policy.expand_weight_bits(layer)
            abits = policy.act_bits[layer.name]
            frac_alive = float(np.mean(wb > 0.5))
            macs = layer.macs * frac_alive
            pj_mac = ENERGY_PJ_PER_MAC_INT8 if (
                float(np.mean(wb)) <= 8 and abits <= 8) \
                else ENERGY_PJ_PER_MAC_BF16
            w_bytes = float(np.mean(storage_bytes_per_elem(wb))) * layer.numel
            total += macs * pj_mac + w_bytes * ENERGY_PJ_PER_BYTE_HBM
        return total * 1e-12      # joules

    def throughput_fps(self, graph: QuantizableGraph,
                       policy: QuantPolicy) -> float:
        return 1.0 / max(self.latency(graph, policy), 1e-12)
