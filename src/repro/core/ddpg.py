"""DDPG actor-critic in pure JAX (no optax/flax available offline).

Paper hyperparameters (section 4): actors and critics have two hidden layers
of 300 units; the actor's output layer is a sigmoid scaled by 32; soft target
updates with tau = 0.01; batch size 64; replay buffer 2000.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

HIDDEN = 300
ACTION_SCALE = 32.0


# ------------------------------------------------------------------ MLP core
def init_mlp(rng, sizes, dtype=jnp.float32):
    params = []
    ks = jax.random.split(rng, len(sizes) - 1)
    for k, (fan_in, fan_out) in zip(ks, zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(k, (fan_in, fan_out)) * np.sqrt(2.0 / fan_in)
        params.append({"w": w.astype(dtype), "b": jnp.zeros(fan_out, dtype)})
    return params


def mlp_apply(params, x, final_act=None):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    if final_act is not None:
        x = final_act(x)
    return x


# ----------------------------------------------------------------- pure Adam
def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    new = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps),
        params, m, v)
    return new, {"m": m, "v": v, "t": t}


# -------------------------------------------------------------------- agent
@dataclasses.dataclass
class DDPGConfig:
    state_dim: int
    action_dim: int
    gamma: float = 0.95
    tau: float = 0.01
    actor_lr: float = 1e-4
    critic_lr: float = 1e-3
    hidden: int = HIDDEN
    action_scale: float = ACTION_SCALE   # sigmoid output x scale


def _sigmoid_scale(x, scale=ACTION_SCALE):
    return jax.nn.sigmoid(x) * scale


class DDPG:
    """One deterministic actor-critic controller (used for both HLC & LLC)."""

    def __init__(self, cfg: DDPGConfig, rng):
        self.cfg = cfg
        k1, k2 = jax.random.split(rng)
        h = cfg.hidden
        actor = init_mlp(k1, (cfg.state_dim, h, h, cfg.action_dim))
        critic = init_mlp(k2, (cfg.state_dim + cfg.action_dim, h, h, 1))
        self.state = {
            "actor": actor, "critic": critic,
            "actor_t": jax.tree.map(jnp.copy, actor),
            "critic_t": jax.tree.map(jnp.copy, critic),
            "opt_a": adam_init(actor), "opt_c": adam_init(critic),
        }
        self._act = jax.jit(self._act_impl)
        self._update = jax.jit(self._update_impl)

    # ------------------------------------------------------------- policies
    def _act_impl(self, actor, s):
        scale = self.cfg.action_scale
        return mlp_apply(actor, s, final_act=lambda x: _sigmoid_scale(x, scale))

    def act(self, s: np.ndarray, noise_scale: float, rng) -> np.ndarray:
        """Noisy action in [0, action_scale].  s: (state_dim,)."""
        scale = self.cfg.action_scale
        a = np.asarray(self._act(self.state["actor"], s[None]))[0]
        if noise_scale > 0:
            a = a + rng.normal(0.0, noise_scale * scale, size=a.shape)
        return np.clip(a, 0.0, scale)

    # --------------------------------------------------------------- update
    def _update_impl(self, state, batch):
        cfg = self.cfg
        s, a, r, s2, done = (batch["s"], batch["a"], batch["r"], batch["s2"],
                             batch["done"])

        a2 = mlp_apply(state["actor_t"], s2,
                       final_act=lambda x: _sigmoid_scale(x, cfg.action_scale))
        q2 = mlp_apply(state["critic_t"], jnp.concatenate([s2, a2], -1))[:, 0]
        target = r + cfg.gamma * (1.0 - done) * q2

        def critic_loss(critic):
            q = mlp_apply(critic, jnp.concatenate([s, a], -1))[:, 0]
            return jnp.mean((q - jax.lax.stop_gradient(target)) ** 2)

        cl, gc = jax.value_and_grad(critic_loss)(state["critic"])
        critic, opt_c = adam_update(state["critic"], gc, state["opt_c"],
                                    cfg.critic_lr)

        def actor_loss(actor):
            pa = mlp_apply(actor, s,
                           final_act=lambda x: _sigmoid_scale(x, cfg.action_scale))
            q = mlp_apply(critic, jnp.concatenate([s, pa], -1))[:, 0]
            return -jnp.mean(q)

        al, ga = jax.value_and_grad(actor_loss)(state["actor"])
        actor, opt_a = adam_update(state["actor"], ga, state["opt_a"],
                                   cfg.actor_lr)

        soft = lambda t, p: jax.tree.map(
            lambda tp, pp: (1 - cfg.tau) * tp + cfg.tau * pp, t, p)
        new_state = {
            "actor": actor, "critic": critic,
            "actor_t": soft(state["actor_t"], actor),
            "critic_t": soft(state["critic_t"], critic),
            "opt_a": opt_a, "opt_c": opt_c,
        }
        return new_state, {"critic_loss": cl, "actor_loss": al}

    def update(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        jb = {k: jnp.asarray(v, jnp.float32) for k, v in batch.items()}
        self.state, metrics = self._update(self.state, jb)
        return {k: float(v) for k, v in metrics.items()}


class ReplayBuffer:
    """Fixed-size ring buffer (paper: size 2000, batch 64)."""

    def __init__(self, state_dim: int, action_dim: int, size: int = 2000):
        self.size = size
        self.n = 0
        self.idx = 0
        self.s = np.zeros((size, state_dim), np.float32)
        self.a = np.zeros((size, action_dim), np.float32)
        self.r = np.zeros((size,), np.float32)
        self.s2 = np.zeros((size, state_dim), np.float32)
        self.done = np.zeros((size,), np.float32)

    def push(self, s, a, r, s2, done):
        i = self.idx
        self.s[i], self.a[i], self.r[i] = s, a, r
        self.s2[i], self.done[i] = s2, float(done)
        self.idx = (i + 1) % self.size
        self.n = min(self.n + 1, self.size)

    def sample(self, rng: np.random.Generator, batch: int = 64):
        idx = rng.integers(0, self.n, size=batch)
        return {"s": self.s[idx], "a": self.a[idx], "r": self.r[idx],
                "s2": self.s2[idx], "done": self.done[idx]}

    def __len__(self):
        return self.n
