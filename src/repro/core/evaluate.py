"""Jitted policy evaluators: QuantPolicy -> validation accuracy (%).

The evaluator compiles once (bit vectors are traced *values*, shapes are
static), so a 400-episode search pays one compile + 400 fast evals -- the
property that makes the paper's "evaluate without fine-tuning" protocol
cheap enough to drive DRL.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.binarize import fake_binarize_per_channel
from repro.quant.linear_quant import fake_quant_per_channel, fake_quant
from repro.quant.policy import QuantMode, QuantPolicy, QuantizableGraph


from repro.quant.apply import _get_path, _set_path  # shared helpers


def _quantize_params(params, graph, wbits_list, mode: QuantMode):
    out = params
    for layer, bits in zip(graph.layers, wbits_list):
        w = _get_path(params, layer.param_path)
        if mode == QuantMode.QUANT:
            qw = fake_quant_per_channel(w, bits, axis=layer.channel_axis)
        else:
            qw = fake_binarize_per_channel(
                w, bits, axis=layer.channel_axis).astype(w.dtype)
        out = _set_path(out, layer.param_path, qw)
    return out


def _expand_bits(policy: QuantPolicy, graph: QuantizableGraph):
    wb = [jnp.asarray(policy.expand_weight_bits(l)) for l in graph.layers]
    ab = [jnp.float32(policy.act_bits[l.name]) for l in graph.layers]
    return wb, ab


def make_cnn_evaluator(model, params, graph: QuantizableGraph, val_batch,
                       mode: QuantMode = QuantMode.QUANT
                       ) -> Callable[[QuantPolicy], float]:
    names = [l.name for l in graph.layers]
    xb = {"x": jnp.asarray(val_batch["x"]), "y": jnp.asarray(val_batch["y"])}

    @jax.jit
    def _eval(wbits_list, abits_list):
        qp = _quantize_params(params, graph, wbits_list, mode)
        act_ctx = dict(zip(names, abits_list))
        return model.accuracy(qp, xb, act_bits=act_ctx) * 100.0

    def evaluator(policy: QuantPolicy) -> float:
        wb, ab = _expand_bits(policy, graph)
        return float(_eval(wb, ab))

    return evaluator


def make_lm_evaluator(model, params, graph: QuantizableGraph, val_batch,
                      mode: QuantMode = QuantMode.QUANT
                      ) -> Callable[[QuantPolicy], float]:
    """Token-prediction accuracy (%) of the quantized LM on a fixed batch.

    Activation bits: the LM forward takes one scalar per (repeat, pattern
    position) block; graph sites of block p share p's activation QBN (the
    paper's own per-FC-layer collapse, extended per block -- DESIGN.md 4).
    """
    vb = {k: jnp.asarray(v) for k, v in val_batch.items()}

    @jax.jit
    def _eval(wbits_list, abits_list):
        qp = _quantize_params(params, graph, wbits_list, mode)
        # block act bits (n_repeat, n_pattern): every repeat shares the site's
        # scalar (stacked layout); unembed bits ignored (logits stay fp).
        # model.block_act_bits is the single search->serve collapse (the
        # serving engine maps a policy through the same helper).
        act = model.block_act_bits(graph, abits_list)
        logits, _ = model.apply(qp, vb, act_bits=act)
        pred = jnp.argmax(logits, -1)
        mask = (vb["labels"] >= 0)
        acc = jnp.sum((pred == vb["labels"]) & mask) / jnp.maximum(
            mask.sum(), 1)
        return acc * 100.0

    def evaluator(policy: QuantPolicy) -> float:
        wb, ab = _expand_bits(policy, graph)
        return float(_eval(wb, ab))

    return evaluator
