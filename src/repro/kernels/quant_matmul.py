"""Fused dequantize(int8, per-channel scale) -> MXU matmul Pallas kernel.

The deployment form of AutoQ-quantized weights on TPU (DESIGN.md section 3):
weights live in HBM as int8 (int4-packed channels are unpacked at load by the
caller) with one f32 scale per output channel; the kernel streams (bk, bn)
weight tiles into VMEM, runs the MXU in f32 accumulation, and applies the
per-channel scale once at the final K step -- so dequantization costs no HBM
round-trip and the weight-side HBM traffic is 1 byte/element instead of 2.

Tiling: grid (M/bm, N/bn, K/bk); K innermost so the f32 accumulator tile
stays resident in VMEM scratch.  Block shapes default to MXU-aligned 128s
(the allclose tests sweep other shapes, incl. non-aligned edges via padding
in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # int8 -> f32 inside VMEM
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _done():
        scale = s_ref[...].astype(jnp.float32)  # (1, bn) per-channel
        o_ref[...] = (acc_ref[...] * scale).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul_pallas(x: jnp.ndarray, qw: jnp.ndarray, scale: jnp.ndarray,
                        *, bm: int = 128, bn: int = 128, bk: int = 128,
                        interpret: bool = True) -> jnp.ndarray:
    """x: (M, K); qw: (K, N) int8; scale: (N,) f32.  M, K, N must be
    multiples of the block shape (ops.py pads)."""
    M, K = x.shape
    N = qw.shape[1]
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bn, bk)
    k_steps = K // bk
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, qw, scale.reshape(1, N))
