"""Fused sub-byte-unpack -> dequantize -> MXU matmul Pallas kernel.

The deployment form of AutoQ channels searched to QBN <= 4: weights live in
HBM bit-packed along K (kernels/pack.py format -- int4 nibbles or int2
crumbs, 2 or 4 values per byte), with one f32 scale per output channel.  The
kernel streams (bk/f, bn) *packed* tiles into VMEM -- so weight-side HBM
traffic is 1/f byte per element, half (int4) or a quarter (int2) of the int8
path in kernels/quant_matmul.py -- unpacks with shift/mask on the VPU,
accumulates the MXU matmul in f32, and applies per-channel scales once at the
final K step.

Unpack-in-kernel: byte field i of packed row r is original K row r*f+i
(little-endian within the byte).  Extraction is ``(b >> store_bits*i) & mask``
followed by a two's-complement sign extension; the f field planes are
interleaved back into K order with a stack+reshape, which lowers to cheap
VREG shuffles on TPU (and is exact in interpret mode on CPU).  A follow-on
for native-int4 MXU dtypes is tracked in ROADMAP.md.

Tiling matches quant_matmul: grid (M/bm, N/bn, K/bk), K innermost so the f32
accumulator tile stays resident in VMEM scratch; ``bk`` must be a multiple of
``f`` so packed tiles stay byte-aligned.

Invariants:

* **Scale placement**: per-output-channel scales are applied exactly once,
  at the *final* K step, to the completed f32 accumulator -- never per
  K-tile.  Folding scales into partial products would change the rounding
  of the accumulation and break bit-parity with the jnp reference
  (``ref.quant_matmul_ref`` scales the full integer-ish product too).
* **Unpack order matches pack.py's K-axis order**: field plane ``i`` of
  packed row ``r`` is original K row ``r*f + i``; the stack+reshape
  interleave restores exact K order before the MXU dot, so the kernel
  contracts the same (K, N) matrix the host packed.
* **Accumulation dtype**: the MXU matmul accumulates in f32
  (``preferred_element_type``) regardless of the output dtype; the cast
  happens after scaling at the final K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pack import SUB8_FACTORS, extract_fields


def _kernel(x_ref, pw_ref, s_ref, o_ref, acc_ref, *, k_steps: int,
            store_bits: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    f = SUB8_FACTORS[store_bits]
    x = x_ref[...].astype(jnp.float32)
    pw = pw_ref[...].astype(jnp.int32)            # (bk/f, bn) packed bytes
    w = jnp.stack(extract_fields(pw, store_bits), axis=1)   # (bk/f, f, bn)
    w = w.reshape(pw.shape[0] * f, pw.shape[1]).astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _done():
        scale = s_ref[...].astype(jnp.float32)    # (1, bn) per-channel
        o_ref[...] = (acc_ref[...] * scale).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("store_bits", "bm", "bn", "bk",
                                    "interpret"))
def packed_matmul_pallas(x: jnp.ndarray, pw: jnp.ndarray, scale: jnp.ndarray,
                         *, store_bits: int, bm: int = 128, bn: int = 128,
                         bk: int = 128,
                         interpret: bool = True) -> jnp.ndarray:
    """x: (M, K); pw: (K/f, N) int8 packed (f = 8/store_bits); scale: (N,).

    M, K, N must be multiples of the block shape (ops.py pads; zero pad bytes
    unpack to zero weights, so padding is exact)."""
    f = SUB8_FACTORS[store_bits]
    M, K = x.shape
    Kp, N = pw.shape
    assert Kp * f == K, (Kp, f, K)
    assert bk % f == 0, (bk, f)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bn, bk)
    k_steps = K // bk
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, store_bits=store_bits),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // f, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, pw, scale.reshape(1, N))
