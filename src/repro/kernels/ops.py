"""Public jit'd wrappers around the Pallas kernels.

Handle non-aligned shapes by padding to the block grid, dispatch between the
Pallas kernel (interpret=True on CPU, compiled on TPU) and the pure-jnp
reference, and expose a single `use_pallas` switch the serving/QAT paths use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import pack, ref
from repro.kernels.binary_matmul import binary_matmul_pallas
from repro.kernels.fake_quant import fake_quant_pallas
from repro.kernels.packed_matmul import packed_matmul_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas
from repro.quant.linear_quant import FULL_BITS

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())
INTERPRET = not _ON_TPU


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def quant_matmul(x, qw, scale, *, bm=128, bn=128, bk=128, use_pallas=True):
    """y = x @ (qw * scale[None, :]).  x (M,K) f32/bf16; qw (K,N) int8."""
    if not use_pallas:
        return ref.quant_matmul_ref(x, qw, scale)
    M, K = x.shape
    N = qw.shape[1]
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(qw, bk, 0), bn, 1)
    sp = _pad_to(scale, bn, 0)
    y = quant_matmul_pallas(xp, wp, sp, bm=bm, bn=bn, bk=bk,
                            interpret=INTERPRET)
    return y[:M, :N]


def packed_matmul(x, pw, scale, *, store_bits, bm=128, bn=128, bk=128,
                  use_pallas=True):
    """y = x @ (unpack(pw) * scale[None, :]) for sub-byte packed weights.

    x: (M, K) f32/bf16; pw: (ceil(K/f), N) int8 bit-packed along K
    (kernels.pack format, f = 8/store_bits); scale: (N,) f32.  Weight-side
    HBM traffic is 1/f byte per element versus 1 for quant_matmul."""
    f = pack.SUB8_FACTORS[store_bits]
    M, K = x.shape
    Kp, N = pw.shape
    assert Kp == -(-K // f), (K, Kp, f)
    if not use_pallas:
        return ref.packed_matmul_ref(x, pw, scale, store_bits)
    assert bk % f == 0, (bk, f)
    # logical K after byte-alignment pad, then after block pad
    k_log = Kp * f + ((-Kp * f) % bk)
    xp = jnp.pad(x, (((0, (-M) % bm), (0, k_log - K))))
    wp = _pad_to(_pad_to(pw, bk // f, 0), bn, 1)
    sp = _pad_to(scale, bn, 0)
    y = packed_matmul_pallas(xp, wp, sp, store_bits=store_bits, bm=bm, bn=bn,
                             bk=bk, interpret=INTERPRET)
    return y[:M, :N]


def packed_mixed_matmul(x, w: "pack.PackedWeight", *, use_pallas=True):
    """y = x @ dequant(w) for a bucketed PackedWeight (2-d weights).

    Dispatches each storage bucket to its kernel -- int2/int4 to
    packed_matmul, int8 to quant_matmul, bf16 passthrough to a plain matmul,
    pruned channels to implicit zeros -- and scatters the per-bucket outputs
    back into policy channel order.  This is the serving contraction a
    searched mixed-QBN policy compiles to."""
    M, K = x.shape
    assert K == w.k, (K, w.k)
    out = jnp.zeros((M, w.n), jnp.float32)
    for (name, idx), part in zip(w.buckets, w.parts):
        if name == "pruned":
            continue
        if name == "full":
            y = x.astype(jnp.float32) @ part[0].astype(jnp.float32)
        elif name == "int8":
            y = quant_matmul(x, part[0], part[1].reshape(-1),
                             use_pallas=use_pallas)
        else:
            y = packed_matmul(x, part[0], part[1].reshape(-1),
                              store_bits=pack.STORE_BITS[name],
                              use_pallas=use_pallas)
        out = out.at[:, jnp.asarray(idx)].set(y.astype(jnp.float32))
    return out.astype(x.dtype)


def binary_matmul(x, planes, alpha, *, bm=128, bn=128, bk=128,
                  use_pallas=True):
    """y = sum_p alpha[p] * (x @ planes[p]).  planes (P,K,N) int8 signs."""
    if not use_pallas:
        return ref.binary_matmul_ref(x, planes, alpha)
    M, K = x.shape
    P, _, N = planes.shape
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    pp = _pad_to(_pad_to(planes, bk, 1), bn, 2)
    ap = _pad_to(alpha, bn, 1)
    y = binary_matmul_pallas(xp, pp, ap, bm=bm, bn=bn, bk=bk,
                             interpret=INTERPRET)
    return y[:M, :N]


def fake_quant_channels(x, scale, levels, bits, *, bm=256, bn=128,
                        use_pallas=True, full_bits=FULL_BITS):
    """Per-channel quantize-dequantize of x (M, N) with (N,) channel params.

    ``full_bits`` (default quant.linear_quant.FULL_BITS) is the single
    pass-through threshold shared by the kernel and the jnp reference."""
    if not use_pallas:
        return ref.fake_quant_ref(x, scale, levels, bits, full_bits=full_bits)
    M, N = x.shape
    xp = _pad_to(_pad_to(x, bm, 0), bn, 1)
    pad1 = lambda v: _pad_to(v, bn, 0)
    # padded channels: scale/levels 1 avoids div-by-zero; bits 0 prunes them
    sp = jnp.where(pad1(scale) == 0, 1.0, pad1(scale)) if N % bn else scale
    lp = jnp.where(pad1(levels) == 0, 1.0, pad1(levels)) if N % bn else levels
    bp = pad1(bits)
    y = fake_quant_pallas(xp, sp, lp, bp, bm=bm, bn=bn, interpret=INTERPRET,
                          full_bits=full_bits)
    return y[:M, :N]
