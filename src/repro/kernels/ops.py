"""Public jit'd wrappers around the Pallas kernels.

Handle non-aligned shapes by padding to the block grid, dispatch between the
Pallas kernel (interpret=True on CPU, compiled on TPU) and the pure-jnp
reference, and expose a single `use_pallas` switch the serving/QAT paths use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.binary_matmul import binary_matmul_pallas
from repro.kernels.fake_quant import fake_quant_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())
INTERPRET = not _ON_TPU


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def quant_matmul(x, qw, scale, *, bm=128, bn=128, bk=128, use_pallas=True):
    """y = x @ (qw * scale[None, :]).  x (M,K) f32/bf16; qw (K,N) int8."""
    if not use_pallas:
        return ref.quant_matmul_ref(x, qw, scale)
    M, K = x.shape
    N = qw.shape[1]
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(qw, bk, 0), bn, 1)
    sp = _pad_to(scale, bn, 0)
    y = quant_matmul_pallas(xp, wp, sp, bm=bm, bn=bn, bk=bk,
                            interpret=INTERPRET)
    return y[:M, :N]


def binary_matmul(x, planes, alpha, *, bm=128, bn=128, bk=128,
                  use_pallas=True):
    """y = sum_p alpha[p] * (x @ planes[p]).  planes (P,K,N) int8 signs."""
    if not use_pallas:
        return ref.binary_matmul_ref(x, planes, alpha)
    M, K = x.shape
    P, _, N = planes.shape
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    pp = _pad_to(_pad_to(planes, bk, 1), bn, 2)
    ap = _pad_to(alpha, bn, 1)
    y = binary_matmul_pallas(xp, pp, ap, bm=bm, bn=bn, bk=bk,
                             interpret=INTERPRET)
    return y[:M, :N]


def fake_quant_channels(x, scale, levels, bits, *, bm=256, bn=128,
                        use_pallas=True):
    """Per-channel quantize-dequantize of x (M, N) with (N,) channel params."""
    if not use_pallas:
        return ref.fake_quant_ref(x, scale, levels, bits)
    M, N = x.shape
    xp = _pad_to(_pad_to(x, bm, 0), bn, 1)
    pad1 = lambda v: _pad_to(v, bn, 0)
    # padded channels: scale/levels 1 avoids div-by-zero; bits 0 prunes them
    sp = jnp.where(pad1(scale) == 0, 1.0, pad1(scale)) if N % bn else scale
    lp = jnp.where(pad1(levels) == 0, 1.0, pad1(levels)) if N % bn else levels
    bp = pad1(bits)
    y = fake_quant_pallas(xp, sp, lp, bp, bm=bm, bn=bn, interpret=INTERPRET)
    return y[:M, :N]
