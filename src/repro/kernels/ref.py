"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.quant.linear_quant import FULL_BITS


def quant_matmul_ref(x: jnp.ndarray, qw: jnp.ndarray,
                     scale: jnp.ndarray) -> jnp.ndarray:
    """x: (M, K) f32/bf16; qw: (K, N) int8; scale: (N,) f32 per out channel."""
    w = qw.astype(jnp.float32) * scale[None, :].astype(jnp.float32)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def packed_matmul_ref(x: jnp.ndarray, pw: jnp.ndarray, scale: jnp.ndarray,
                      store_bits: int) -> jnp.ndarray:
    """Unpack (kernels.pack format) then quant_matmul_ref.

    x: (M, K); pw: (ceil(K/f), N) int8 packed along K; scale: (N,) f32."""
    from repro.kernels.pack import unpack_sub8
    q = unpack_sub8(pw, store_bits, k=x.shape[1], axis=0)
    return quant_matmul_ref(x, q, scale)


def binary_matmul_ref(x: jnp.ndarray, planes: jnp.ndarray,
                      alpha: jnp.ndarray) -> jnp.ndarray:
    """Bit-plane matmul: y = sum_m alpha_m * (x @ B_m).

    x: (M, K); planes: (P, K, N) int8 in {-1, +1}; alpha: (P, N) f32
    (per plane, per output channel).
    """
    xf = x.astype(jnp.float32)
    acc = jnp.zeros((x.shape[0], planes.shape[-1]), jnp.float32)
    for p in range(planes.shape[0]):
        acc = acc + (xf @ planes[p].astype(jnp.float32)) * \
            alpha[p][None, :].astype(jnp.float32)
    return acc.astype(x.dtype)


def fake_quant_ref(x: jnp.ndarray, scale: jnp.ndarray, levels: jnp.ndarray,
                   bits: jnp.ndarray,
                   full_bits: float = FULL_BITS) -> jnp.ndarray:
    """Per-channel quantize-dequantize with precomputed scales.

    x: (M, N); scale, levels, bits: (N,).  bits<=0 prunes; bits>=full_bits
    passes through (the quant.linear_quant.FULL_BITS threshold).
    """
    xf = x.astype(jnp.float32)
    s = scale[None, :].astype(jnp.float32)
    lv = levels[None, :].astype(jnp.float32)
    b = bits[None, :].astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / s), -lv, lv) * s
    out = jnp.where(b <= 0.5, 0.0, jnp.where(b >= full_bits, xf, q))
    return out.astype(x.dtype)
