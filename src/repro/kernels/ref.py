"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp


def quant_matmul_ref(x: jnp.ndarray, qw: jnp.ndarray,
                     scale: jnp.ndarray) -> jnp.ndarray:
    """x: (M, K) f32/bf16; qw: (K, N) int8; scale: (N,) f32 per out channel."""
    w = qw.astype(jnp.float32) * scale[None, :].astype(jnp.float32)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def binary_matmul_ref(x: jnp.ndarray, planes: jnp.ndarray,
                      alpha: jnp.ndarray) -> jnp.ndarray:
    """Bit-plane matmul: y = sum_m alpha_m * (x @ B_m).

    x: (M, K); planes: (P, K, N) int8 in {-1, +1}; alpha: (P, N) f32
    (per plane, per output channel).
    """
    xf = x.astype(jnp.float32)
    acc = jnp.zeros((x.shape[0], planes.shape[-1]), jnp.float32)
    for p in range(planes.shape[0]):
        acc = acc + (xf @ planes[p].astype(jnp.float32)) * \
            alpha[p][None, :].astype(jnp.float32)
    return acc.astype(x.dtype)


def fake_quant_ref(x: jnp.ndarray, scale: jnp.ndarray, levels: jnp.ndarray,
                   bits: jnp.ndarray) -> jnp.ndarray:
    """Per-channel quantize-dequantize with precomputed scales.

    x: (M, N); scale, levels, bits: (N,).  bits<=0 prunes; bits>=24 passes
    through (matches quant.linear_quant.FULL_BITS semantics).
    """
    xf = x.astype(jnp.float32)
    s = scale[None, :].astype(jnp.float32)
    lv = levels[None, :].astype(jnp.float32)
    b = bits[None, :].astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / s), -lv, lv) * s
    out = jnp.where(b <= 0.5, 0.0, jnp.where(b >= 24.0, xf, q))
    return out.astype(x.dtype)
