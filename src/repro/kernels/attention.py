"""Pallas attention subsystem: fused flash prefill + block-table paged decode.

Two kernels cover the serving hot path (models/layers.py owns the
``impl="pallas"|"ref"`` dispatch; the jnp chunked-flash path there is the
bit-accuracy oracle both kernels are property-tested against):

* :func:`flash_attention` -- tiled flash-attention forward for prefill (and
  dense-cache decode, ``Sq == 1``).  Grid ``(B, Hkv, nq, nk)`` with the KV
  axis innermost: the f32 accumulator, running max ``m`` and normalizer ``l``
  live in VMEM scratch across the KV tiles of one q tile (online softmax),
  so no (Sq, Skv) score matrix ever exists.  GQA is folded into the tile:
  one program handles all ``G = Hq/Hkv`` query heads that share a KV head,
  loading each K/V tile once per KV head instead of once per query head.
  Causal, sliding-window and softcap masking run on the score tile in VMEM.

* :func:`paged_prefill_attention` -- block-table-aware attention over the
  paged KV pool (serve/paged_kv.py layout) for q tiles of ``k`` tokens per
  sequence: the chunked-prefill workhorse, and (at ``k == 1``, via the
  :func:`paged_decode_attention` wrapper) the decode step.  The block table
  rides in as a scalar-prefetch operand, so the BlockSpec index_map resolves
  ``bt[seq, first[seq] + j]`` *before* each grid step and the pipeline DMAs
  exactly that physical page HBM->VMEM -- there is no dense gather and no
  (B, nb*page_size) intermediate.  Causal masking runs against each q row's
  own position, so a chunk's rows attend earlier chunks' pages plus their
  own chunk's already-written slots (chunk offsets need no extra state).
  For sliding-window blocks, ``first`` (the oldest logical block still
  inside the window of the tile's lowest real position, precomputed per
  sequence) re-bases the walk: out-of-window pages are never fetched.  Walk
  steps past a sequence's last block clip onto its final block id and mask
  the whole tile (Pallas skips the re-fetch when consecutive steps map to
  the same block, so the clip costs no extra HBM traffic).

int8 KV pages (``kv_bits=8`` pool): when the pool stores int8, the kernel
streams the packed page plus its per-(slot, head) scale page into VMEM and
dequantizes there -- KV HBM traffic stays 1 byte/element; f32 only ever
exists on-chip.

Numerics shared by both kernels (matching the jnp oracle step for step):
scores, softmax statistics and accumulation are f32 regardless of input
dtype; masked slots contribute exact zeros (``exp(-inf - m_safe) == 0``);
position ``POS_SENTINEL`` (int32 max) is unconditionally unattendable; an
all-masked row normalizes by ``max(l, 1e-30)`` to exact zeros.  With one KV
tile the update degenerates to the oracle's single-shot softmax (``alpha``
is exactly 0 on the first tile, exactly 1 on tiles that do not move the
running max), so small shapes reproduce the reference bit for bit; multiple
tiles differ only by documented f32 rescale rounding (~1e-7).

Kernels validate under ``interpret=True`` on CPU (the test path); TPU is the
compile target.  Off-TPU the wrappers skip lane padding so the contraction
lengths -- and therefore the f32 rounding -- match the oracle exactly; on
TPU they pad the head dim to the 128-lane boundary (zero columns are exact).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())
INTERPRET = not _ON_TPU

NEG_INF = float("-inf")
POS_SENTINEL = np.iinfo(np.int32).max
_LANES = 128                 # TPU vector lane count (last-dim tile unit)


def _pad_axis(x, mult, axis, value=0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _mask_tile(s, qp, kp, *, causal, window):
    """Mask a (rows, bk) score tile.  qp (rows, 1) / kp (1, bk) int32.

    The sentinel test makes padded / scrubbed / trash slots unattendable even
    for idle decode lanes whose own q_pos is the sentinel (the oracle leaves
    those lanes attending trash; their outputs are ignored either way).
    """
    mask = kp != POS_SENTINEL
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    return jnp.where(mask, s, NEG_INF)


def _online_update(s, vt, acc_ref, m_ref, l_ref):
    """One online-softmax accumulation step over a masked score tile.

    Mirrors the oracle's scan body exactly: on the first tile ``alpha`` is 0
    and the update reduces to single-shot softmax; on tiles that leave the
    running max unchanged ``alpha == exp(0) == 1`` and the accumulate is
    exact.  ``m``/``l`` are lane-replicated (rows, _LANES) VMEM scratch.
    """
    m_prev = m_ref[:, :1]
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    pv = jax.lax.dot_general(p, vt, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(
        l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True), l_ref.shape)


def _finalize(acc_ref, l_ref, shape, dtype):
    o = acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)
    return o.reshape(shape).astype(dtype)


# ------------------------------------------------------------ flash prefill
def _flash_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, nk, causal, window, cap, scale,
                  G):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    bq, D = q_ref.shape[1], q_ref.shape[3]
    qt = (q_ref[0].astype(jnp.float32) * scale).reshape(bq * G, D)
    kt = k_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(qt, kt, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq*G, bk)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    qp = jnp.repeat(qp_ref[0, :], G)[:, None]
    s = _mask_tile(s, qp, kp_ref[0, :][None, :], causal=causal, window=window)
    _online_update(s, v_ref[0, :, 0, :].astype(jnp.float32),
                   acc_ref, m_ref, l_ref)

    @pl.when(j == nk - 1)
    def _done():
        o_ref[0] = _finalize(acc_ref, l_ref, (bq, G, D), o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "attn_cap",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, q_pos, kv_pos, causal=True, window=None,
                    attn_cap=None, bq=128, bk=128, interpret=INTERPRET):
    """Tiled flash-attention forward (prefill / dense-cache decode).

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); q_pos: (B, Sq) int32;
    kv_pos: (B, Skv) int32.  Returns (B, Sq, Hq, D) in q.dtype.  Pure
    function of positions: causal / sliding-window validity comes from
    comparing q_pos against kv_pos, so ring-buffer (rolled) caches and
    padded tails (position == sentinel) need no extra arguments.
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    bq = min(bq, -(-Sq // 8) * 8)
    bk = min(bk, -(-Skv // 8) * 8)
    q_ = _pad_axis(q, bq, 1)
    k_ = _pad_axis(k, bk, 1)
    v_ = _pad_axis(v, bk, 1)
    # padded q rows mask everything (causal qp=0 / sentinel kp) -> sliced off;
    # padded kv slots carry the sentinel position -> never attended
    qp_ = _pad_axis(q_pos.astype(jnp.int32), bq, 1)
    kp_ = _pad_axis(kv_pos.astype(jnp.int32), bk, 1, value=POS_SENTINEL)
    if not interpret:            # TPU lane alignment; zero columns are exact
        q_, k_, v_ = (_pad_axis(x, _LANES, 3) for x in (q_, k_, v_))
    Dp = q_.shape[3]
    nq, nk = q_.shape[1] // bq, k_.shape[1] // bk
    out = pl.pallas_call(
        functools.partial(_flash_kernel, nk=nk, causal=causal, window=window,
                          cap=attn_cap, scale=scale, G=G),
        grid=(B, Hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, G, Dp), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, Dp), lambda b, h, i, j: (b, j, h, 0)),
            pl.BlockSpec((1, bk, 1, Dp), lambda b, h, i, j: (b, j, h, 0)),
            pl.BlockSpec((1, bq), lambda b, h, i, j: (b, i)),
            pl.BlockSpec((1, bk), lambda b, h, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, G, Dp),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, q_.shape[1], Hq, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq * G, Dp), jnp.float32),
            pltpu.VMEM((bq * G, _LANES), jnp.float32),
            pltpu.VMEM((bq * G, _LANES), jnp.float32),
        ],
        interpret=interpret,
    )(q_, k_, v_, qp_, kp_)
    return out[:, :Sq, :, :D]


# --------------------------------------------- paged prefill / decode
def _paged_kernel(bt_ref, first_ref, q_ref, qp_ref, k_ref, v_ref, pos_ref,
                  *rest, nb, window, cap, scale, G, quant):
    if quant:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    bq, D = q_ref.shape[1], q_ref.shape[3]
    qt = (q_ref[0].astype(jnp.float32) * scale).reshape(bq * G, D)
    kt = k_ref[0, :, 0, :].astype(jnp.float32)            # (ps, D)
    vt = v_ref[0, :, 0, :].astype(jnp.float32)
    if quant:                  # int8 pages: dequantize in VMEM, not in HBM
        kt = kt * ks_ref[0, :, 0][:, None]
        vt = vt * vs_ref[0, :, 0][:, None]
    s = jax.lax.dot_general(qt, kt, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq*G, ps)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    qp = jnp.repeat(qp_ref[0, :], G)[:, None]
    s = _mask_tile(s, qp, pos_ref[0][None, :], causal=True, window=window)
    # walk steps past the last logical block were clipped onto block nb-1 by
    # the index_map: mask the duplicate tile entirely
    s = jnp.where(first_ref[b] + j < nb, s, NEG_INF)
    _online_update(s, vt, acc_ref, m_ref, l_ref)

    @pl.when(j == pl.num_programs(2) - 1)
    def _done():
        o_ref[0] = _finalize(acc_ref, l_ref, (bq, G, D), o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "attn_cap",
                                             "interpret"))
def paged_prefill_attention(q, k_pages, v_pages, pos_pages, block_tables, *,
                            q_pos, window=None, attn_cap=None,
                            k_scale_pages=None, v_scale_pages=None,
                            interpret=INTERPRET):
    """Causal attention over the paged KV pool for q-tiles of k tokens.

    The block-table page walk generalized from single-token decode to the
    chunked-prefill q tile: each sequence contributes ``k`` query rows (a
    prompt chunk, a lone decode token, or sentinel padding) that all read KV
    through the same scalar-prefetched block-table row.

    q: (B, k, Hq, D); ``*_pages``: (P, page_size, Hkv, D) physical pool
    (``pos_pages`` (P, page_size) int32); block_tables: (B, nb) int32;
    q_pos: (B, k) int32 per-row token positions, **left-aligned**: real
    tokens occupy columns ``0..c-1`` in ascending position order and padded
    columns carry ``POS_SENTINEL``.  int8 pools pass ``k_scale_pages`` /
    ``v_scale_pages`` (P, page_size, Hkv) f32 and the kernel dequantizes in
    VMEM.  Returns (B, k, Hq, D) in q.dtype.

    Grid (B, Hkv, nb): step ``j`` of sequence ``b`` DMAs physical page
    ``bt[b, min(first[b]+j, nb-1)]`` (index_map over the scalar-prefetched
    table).  ``first`` -- computed from the row's *lowest* real position
    (column 0, thanks to left-alignment) -- skips the logical blocks wholly
    below the sliding window, so out-of-window pages never leave HBM;
    not-yet-grown tail blocks point at the trash page whose slots are
    all-sentinel.  Causal masking against each row's own position handles
    chunk offsets: a chunk token attends earlier chunks' pages plus its own
    chunk's already-written slots, never its future.  Fully padded rows
    (q_pos all sentinel under a window; all-trash tables otherwise) produce
    zeros or garbage the scheduler ignores.
    """
    B, k, Hq, D = q.shape
    P, ps, Hkv, _ = k_pages.shape
    nb = block_tables.shape[1]
    G = Hq // Hkv
    quant = k_pages.dtype == jnp.int8
    assert quant == (k_scale_pages is not None), \
        "int8 pools require scale pages (and f32/bf16 pools must not pass them)"
    scale = 1.0 / math.sqrt(D)
    qp = q_pos.reshape(B, k).astype(jnp.int32)
    if window is not None:
        # oldest logical block with any position > min_real_qp - window in
        # it; left-alignment makes column 0 the row's lowest real position
        # (sentinel rows clip to nb-1 and mask everything, like decode)
        first = jnp.clip((qp[:, 0] - (window - 1)) // ps, 0, nb - 1)
    else:
        first = jnp.zeros((B,), jnp.int32)

    q_, k_, v_ = q, k_pages, v_pages
    qp_, pos_ = qp, pos_pages
    if not interpret:            # TPU alignment: slot sublanes + head lanes
        q_ = _pad_axis(q_, 8, 1)
        qp_ = _pad_axis(qp_, 8, 1, value=POS_SENTINEL)
        k_ = _pad_axis(k_, 8, 1)
        v_ = _pad_axis(v_, 8, 1)
        pos_ = _pad_axis(pos_, 8, 1, value=POS_SENTINEL)
        q_, k_, v_ = (_pad_axis(x, _LANES, 3) for x in (q_, k_, v_))
        if quant:
            k_scale_pages = _pad_axis(k_scale_pages, 8, 1)
            v_scale_pages = _pad_axis(v_scale_pages, 8, 1)
    kp, psp, Dp = q_.shape[1], k_.shape[1], k_.shape[3]

    def page_map(b, h, j, bt, fr):
        blk = jnp.minimum(fr[b] + j, nb - 1)
        return (bt[b, blk], 0, h, 0)

    def pos_map(b, h, j, bt, fr):
        blk = jnp.minimum(fr[b] + j, nb - 1)
        return (bt[b, blk], 0)

    def q_map(b, h, j, bt, fr):
        return (b, 0, h, 0)

    in_specs = [
        pl.BlockSpec((1, kp, G, Dp), q_map),
        pl.BlockSpec((1, kp), lambda b, h, j, bt, fr: (b, 0)),
        pl.BlockSpec((1, psp, 1, Dp), page_map),
        pl.BlockSpec((1, psp, 1, Dp), page_map),
        pl.BlockSpec((1, psp), pos_map),
    ]
    operands = [q_, qp_, k_, v_, pos_]
    if quant:
        def scale_map(b, h, j, bt, fr):          # (P, ps, Hkv): 3-d blocks
            blk = jnp.minimum(fr[b] + j, nb - 1)
            return (bt[b, blk], 0, h)

        in_specs += [pl.BlockSpec((1, psp, 1), scale_map),
                     pl.BlockSpec((1, psp, 1), scale_map)]
        operands += [k_scale_pages, v_scale_pages]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, kp, G, Dp), q_map),
        scratch_shapes=[
            pltpu.VMEM((kp * G, Dp), jnp.float32),
            pltpu.VMEM((kp * G, _LANES), jnp.float32),
            pltpu.VMEM((kp * G, _LANES), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, nb=nb, window=window, cap=attn_cap,
                          scale=scale, G=G, quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, kp, Hq, Dp), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), first, *operands)
    return out[:, :k, :, :D]


def paged_decode_attention(q, k_pages, v_pages, pos_pages, block_tables, *,
                           q_pos, window=None, attn_cap=None,
                           k_scale_pages=None, v_scale_pages=None,
                           interpret=INTERPRET):
    """Single-token decode over the paged pool: the ``k == 1`` q tile of
    :func:`paged_prefill_attention` (kept as the decode-path entry point).

    q: (B, 1, Hq, D); q_pos: (B, 1) or (B,) int32.  Returns (B, 1, Hq, D).
    """
    B = q.shape[0]
    return paged_prefill_attention(
        q, k_pages, v_pages, pos_pages, block_tables,
        q_pos=q_pos.reshape(B, 1), window=window, attn_cap=attn_cap,
        k_scale_pages=k_scale_pages, v_scale_pages=v_scale_pages,
        interpret=interpret)
