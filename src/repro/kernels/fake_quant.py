"""Per-channel quantize-dequantize (QAT forward) Pallas kernel.

Elementwise per-channel fake quantization with precomputed scales/levels
(the per-channel amax reduction is a cheap one-pass jnp op outside; fusing it
would force a two-phase kernel for no HBM saving).  Used on the QAT
fine-tuning path where the same weight tile is fake-quantized every step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, lv_ref, b_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)           # (1, bn)
    lv = lv_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    q = jnp.clip(jnp.round(x / s), -lv, lv) * s
    out = jnp.where(b <= 0.5, 0.0, jnp.where(b >= 24.0, x, q))
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def fake_quant_pallas(x: jnp.ndarray, scale: jnp.ndarray, levels: jnp.ndarray,
                      bits: jnp.ndarray, *, bm: int = 256, bn: int = 128,
                      interpret: bool = True) -> jnp.ndarray:
    """x: (M, N); scale/levels/bits: (N,) per-channel."""
    M, N = x.shape
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    return pl.pallas_call(
        _kernel,
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(x, scale.reshape(1, N), levels.reshape(1, N), bits.reshape(1, N))
