"""Per-channel quantize-dequantize (QAT forward) Pallas kernel.

Elementwise per-channel fake quantization with precomputed scales/levels
(the per-channel amax reduction is a cheap one-pass jnp op outside; fusing it
would force a two-phase kernel for no HBM saving).  Used on the QAT
fine-tuning path where the same weight tile is fake-quantized every step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.linear_quant import FULL_BITS


def _kernel(x_ref, s_ref, lv_ref, b_ref, o_ref, *, full_bits: float):
    x = x_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)           # (1, bn)
    lv = lv_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    q = jnp.clip(jnp.round(x / s), -lv, lv) * s
    out = jnp.where(b <= 0.5, 0.0, jnp.where(b >= full_bits, x, q))
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "interpret", "full_bits"))
def fake_quant_pallas(x: jnp.ndarray, scale: jnp.ndarray, levels: jnp.ndarray,
                      bits: jnp.ndarray, *, bm: int = 256, bn: int = 128,
                      interpret: bool = True,
                      full_bits: float = FULL_BITS) -> jnp.ndarray:
    """x: (M, N); scale/levels/bits: (N,) per-channel.  ``full_bits`` is the
    pass-through threshold, threaded from quant.linear_quant.FULL_BITS so the
    kernel and the reference quantizer cannot silently diverge."""
    M, N = x.shape
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    return pl.pallas_call(
        functools.partial(_kernel, full_bits=float(full_bits)),
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(x, scale.reshape(1, N), levels.reshape(1, N), bits.reshape(1, N))
