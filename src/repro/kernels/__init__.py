"""Pallas TPU kernels for AutoQ's deployment hot spots.

quant_matmul   -- fused int8-dequant (per-output-channel scale) + MXU matmul
packed_matmul  -- fused sub-byte unpack (int4 nibble / int2 crumb along K)
                  + dequant + MXU matmul: 1/2 or 1/4 the weight HBM bytes
packed_mixed_matmul -- bucketed dispatch over a PackedWeight (a searched
                  mixed-QBN policy's serving contraction)
binary_matmul  -- bit-plane (binarized) matmul, alpha-weighted sign planes
fake_quant     -- per-channel quantize-dequantize (QAT forward)
flash_attention / paged_prefill_attention -- the attention subsystem
                  (attention.py, docs/attention.md): tiled flash forward,
                  and block-table paged attention for q-tiles of k tokens
                  per sequence -- chunked prefill and (k = 1, via the
                  paged_decode_attention wrapper) decode are one kernel
                  (int8 pages dequantize in VMEM)

pack.py holds the bit-packing format + the PackedWeight pytree container
(see docs/packed_layout.md); ops.py exposes the jit'd public wrappers
(padding + pallas/ref dispatch); ref.py holds the pure-jnp oracles every
kernel is allclose-tested against (for attention the oracle is
models/layers.attention_ref).  Kernels validate under interpret=True on
CPU; TPU is the compile target.
"""
from repro.kernels.attention import (flash_attention, paged_decode_attention,
                                     paged_prefill_attention)
from repro.kernels.ops import (binary_matmul, fake_quant_channels,
                               packed_matmul, packed_mixed_matmul,
                               quant_matmul)
from repro.kernels.pack import PackedWeight, pack_sub8, unpack_sub8

__all__ = ["binary_matmul", "fake_quant_channels", "flash_attention",
           "packed_matmul", "packed_mixed_matmul", "paged_decode_attention",
           "paged_prefill_attention", "quant_matmul", "PackedWeight",
           "pack_sub8", "unpack_sub8"]
