"""Pallas TPU kernels for AutoQ's deployment hot spots.

quant_matmul  -- fused int8-dequant (per-output-channel scale) + MXU matmul
binary_matmul -- bit-plane (binarized) matmul, alpha-weighted sign planes
fake_quant    -- per-channel quantize-dequantize (QAT forward)

ops.py exposes the jit'd public wrappers (padding + pallas/ref dispatch);
ref.py holds the pure-jnp oracles every kernel is allclose-tested against.
Kernels validate under interpret=True on CPU; TPU is the compile target.
"""
from repro.kernels.ops import binary_matmul, fake_quant_channels, quant_matmul

__all__ = ["binary_matmul", "fake_quant_channels", "quant_matmul"]
