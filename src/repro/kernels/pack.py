"""Sub-byte weight packing: the executable form of searched QBN policies.

AutoQ lands most weight channels at 2--5 bits, but an int8 store spends a
full byte per element regardless -- the weight-side HBM traffic the roofline
reward optimizes for is then ~2x larger than the policy warrants.  This
module packs quantized channels with QBN <= 4 into nibble (int4, 2
values/byte) or crumb (int2, 4 values/byte) buffers along the contraction
(K) axis, so HBM bytes track the searched bit-width.

Packing format (little-endian within the byte, along K):

    packed[r] = sum_i (q[r*f + i] & mask) << (store_bits * i),   f = 8/store_bits

i.e. byte r of a channel holds original K positions ``r*f .. r*f+f-1``, the
lowest-order field first.  K is zero-padded to a multiple of ``f`` (zero
bytes unpack to zero weights, so matmuls over the pad are exact no-ops).
The channel (N) axis is untouched: per-channel scales and per-channel-group
QBNs from a :class:`~repro.quant.policy.QuantPolicy` map 1:1 onto packed
columns.

:class:`PackedWeight` is the bucketed whole-tensor layout
(``quant.linear_quant.quant_pack_sub8`` builds it): channels are routed by
QBN into ``pruned`` (no storage) / ``int2`` / ``int4`` / ``int8`` / ``full``
(bf16 passthrough) buckets.  It is a registered pytree whose array children
all keep any leading stack dims, so it rides through ``jax.jit`` and
``lax.scan`` (the LM's stacked-block layout) unchanged.

Invariants every consumer may rely on (and none may weaken):

* **K-axis packing order**: packing always runs along the contraction
  axis, little-endian within the byte -- byte ``r`` of a channel holds
  original K rows ``r*f .. r*f+f-1``, lowest-order field first.  The N
  (output-channel) axis is never packed, so per-channel scales and bucket
  membership map 1:1 onto packed columns.
* **Zero padding is exact**: K pads to a multiple of ``f`` with zero
  bytes, which unpack to zero weights -- contractions over the pad are
  no-ops, so callers (ops.py, the Pallas grids) may over-tile freely.
* **Fields are two's-complement in ``store_bits``**: :func:`extract_fields`
  is the one definition of the read side, shared by the host unpack and
  the in-VMEM kernel unpack, so the format cannot drift between them.
* **Grid identity with fake-quant**: each channel quantizes on its own
  ``levels = 2^(b-1)-1`` grid, identical to ``quant.linear_quant``'s
  fake-quant -- dequantizing a ``b <= 8`` bucket reproduces the
  search-time numerics bit-exactly (serving-parity tests pin this).

See docs/packed_layout.md for the full format description.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

# storage width -> values per byte
SUB8_FACTORS = {2: 4, 4: 2}


def bucket_of_bits(bits: float) -> str:
    """Storage bucket for one channel's QBN: the bucketed sub-byte layout.

    <=0 pruned (no storage), <=2 crumb-packed, <=4 nibble-packed, <=8 plain
    int8, >8 bf16 passthrough."""
    b = round(float(bits))
    if b <= 0:
        return "pruned"
    if b <= 2:
        return "int2"
    if b <= 4:
        return "int4"
    if b <= 8:
        return "int8"
    return "full"


STORE_BITS = {"int2": 2, "int4": 4, "int8": 8}


def pack_sub8(q: jnp.ndarray, store_bits: int, axis: int = -2) -> jnp.ndarray:
    """Pack integer values (fitting signed ``store_bits``) into int8 bytes.

    q: integer array; values must lie in [-2^(store_bits-1), 2^(store_bits-1)-1].
    Returns int8 with ``axis`` shrunk to ceil(K / (8/store_bits)).
    """
    f = SUB8_FACTORS[store_bits]
    mask = (1 << store_bits) - 1
    q = jnp.asarray(q)
    axis = axis % q.ndim
    K = q.shape[axis]
    pad = (-K) % f
    if pad:
        widths = [(0, 0)] * q.ndim
        widths[axis] = (0, pad)
        q = jnp.pad(q, widths)
    qm = jnp.moveaxis(q, axis, 0).astype(jnp.int32) & mask
    Kp = qm.shape[0] // f
    qm = qm.reshape((Kp, f) + qm.shape[1:])
    packed = jnp.zeros((Kp,) + qm.shape[2:], jnp.int32)
    for i in range(f):
        packed = packed | (qm[:, i] << (store_bits * i))
    # reinterpret the byte pattern as signed before narrowing (int32->int8
    # conversion of values > 127 is not portable across backends)
    packed = packed - ((packed >> 7) << 8)
    return jnp.moveaxis(packed.astype(jnp.int8), 0, axis)


def extract_fields(pm: jnp.ndarray, store_bits: int) -> list:
    """Sign-extended field planes of packed bytes (int32 bit patterns).

    The single definition of the byte layout's read side -- shared by
    :func:`unpack_sub8` and the in-VMEM unpack in packed_matmul's kernel,
    so the format cannot drift between host packing and kernel unpacking.
    Returns ``f`` arrays shaped like ``pm``; plane ``i`` holds original K
    position ``r*f + i`` for packed row ``r``."""
    mask = (1 << store_bits) - 1
    out = []
    for i in range(SUB8_FACTORS[store_bits]):
        m = (pm >> (store_bits * i)) & mask
        out.append(m - ((m >> (store_bits - 1)) << store_bits))
    return out


def unpack_sub8(packed: jnp.ndarray, store_bits: int, k: int,
                axis: int = -2) -> jnp.ndarray:
    """Inverse of :func:`pack_sub8`: int8 bytes -> int8 values, ``axis``
    restored to length ``k`` (the pre-padding K)."""
    f = SUB8_FACTORS[store_bits]
    packed = jnp.asarray(packed)
    axis = axis % packed.ndim
    pm = jnp.moveaxis(packed, axis, 0).astype(jnp.int32)
    v = jnp.stack(extract_fields(pm, store_bits), axis=1)   # (Kp, f, ...)
    v = v.reshape((pm.shape[0] * f,) + pm.shape[1:])[:k]
    return jnp.moveaxis(v.astype(jnp.int8), 0, axis)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedWeight:
    """Bucketed sub-byte weight store for one (..., K, N) matmul weight.

    parts[i] mirrors buckets[i]:
      pruned -> (sentinel (..., K, 0) int8)  (channels reconstruct as zero;
               the zero-width array carries the leading stack dims so an
               all-pruned weight still dequantizes to the right shape)
      int2   -> (packed (..., ceil(K/4), nb) int8, scale (..., nb) f32)
      int4   -> (packed (..., ceil(K/2), nb) int8, scale (..., nb) f32)
      int8   -> (q      (..., K, nb)      int8, scale (..., nb) f32)
      full   -> (w      (..., K, nb)      bf16)

    Static aux: ``k``/``n`` (logical contraction length / channel count),
    ``buckets`` = ((name, channel-index tuple), ...), ``out_dtype``.  All
    array children keep leading stack dims, so a stacked (R, K, N) weight
    scans exactly like a plain array (``lax.scan`` slices the children; the
    aux -- per-channel bucket membership -- is R-invariant by construction:
    scales reduce over the stack dim like the fake-quant path).
    """
    parts: Tuple[Tuple[Any, ...], ...]
    k: int
    n: int
    buckets: Tuple[Tuple[str, Tuple[int, ...]], ...]
    out_dtype: str = "float32"

    def tree_flatten(self):
        return self.parts, (self.k, self.n, self.buckets, self.out_dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        k, n, buckets, out_dtype = aux
        return cls(parts=tuple(children), k=k, n=n, buckets=buckets,
                   out_dtype=out_dtype)

    # ------------------------------------------------------------- dequant
    def dequant(self) -> jnp.ndarray:
        """Reconstruct the dequantized (..., K, N) weight (jit-safe)."""
        lead: Tuple[int, ...] = ()
        for part in self.parts:
            if part:
                lead = part[0].shape[:-2]
                break
        out = jnp.zeros(lead + (self.k, self.n), jnp.float32)
        for (name, idx), part in zip(self.buckets, self.parts):
            if name == "pruned":
                continue
            idx_a = jnp.asarray(idx)
            if name == "full":
                cols = part[0].astype(jnp.float32)
            else:
                data, scale = part
                if name != "int8":
                    data = unpack_sub8(data, STORE_BITS[name], self.k,
                                       axis=-2)
                cols = data.astype(jnp.float32) * \
                    scale.astype(jnp.float32)[..., None, :]
            out = out.at[..., idx_a].set(cols)
        return out.astype(jnp.dtype(self.out_dtype))

    # ----------------------------------------------------------- accounting
    def bucket_nbytes(self) -> dict:
        """Stored bytes per bucket (packed buffers + scales)."""
        out = {}
        for (name, _), part in zip(self.buckets, self.parts):
            out[name] = int(sum(a.size * a.dtype.itemsize for a in part))
        return out

    def hbm_bytes(self) -> int:
        """Total weight-side HBM bytes of this store."""
        return int(sum(self.bucket_nbytes().values()))
