"""Bit-plane ("binarized") matmul Pallas kernel.

TPU adaptation of the paper's XNOR-popcount binary convolution (DESIGN.md
sections 3 and 7): W ~= sum_m alpha_m B_m with B_m in {-1,+1} stored 1
bit/plane in HBM (the caller keeps planes as int8 for the MXU; packed-bit
storage is modeled in the roofline).  The kernel accumulates
sum_m alpha_m[n] * (x @ B_m) over K tiles with the planes loop unrolled
in-kernel, so each (bk, bn) weight tile of every plane is read exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, b_ref, a_ref, o_ref, acc_ref, *, k_steps: int,
            n_planes: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    for p in range(n_planes):                    # static unroll (<= 8 planes)
        bp = b_ref[p].astype(jnp.float32)        # (bk, bn) sign tile
        ap = a_ref[0, p].astype(jnp.float32)     # (bn,) per-channel alpha
        acc_ref[...] += jax.lax.dot(
            x, bp, preferred_element_type=jnp.float32) * ap[None, :]

    @pl.when(k == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def binary_matmul_pallas(x: jnp.ndarray, planes: jnp.ndarray,
                         alpha: jnp.ndarray, *, bm: int = 128, bn: int = 128,
                         bk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """x: (M, K); planes: (P, K, N) int8 {-1,+1}; alpha: (P, N) f32."""
    M, K = x.shape
    P, _, N = planes.shape
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bn, bk)
    k_steps = K // bk
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, n_planes=P),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((P, bk, bn), lambda i, j, k: (0, k, j)),
            pl.BlockSpec((1, P, bn), lambda i, j, k: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, planes, alpha.reshape(1, P, N))
