"""Distribution substrate: sharding context, partition specs, collectives."""
