"""Custom collectives: int8-compressed gradient all-reduce over the pod axis.

Cross-pod links (DCN) are the scarcest bandwidth in a multi-pod job; the
paper's own linear quantizer compresses the pod-level gradient exchange:
each pod quantizes its local gradient int8 (absmax scale per last-axis row),
all-gathers the (q, scale) pairs over "pod" (1 byte + amortized scale instead
of 2), and dequantize-sums locally.  Exact for pod=2 up to int8 rounding;
4x fewer DCN bytes than an fp32 ring all-reduce, 2x fewer than bf16.

Used by steps.make_train_step(compress_pod=True): the loss/grad is computed
under shard_map manual over "pod" (auto over data/model), so each pod holds
its local-batch gradient and this function performs the only cross-pod
communication in the step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _q8(x):
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def compressed_allreduce(tree, axis_name: str = "pod"):
    """Mean over `axis_name` via int8 all-gather + local dequant-sum.

    Call inside shard_map (manual over axis_name).  Scalars and tiny leaves
    (< 1KiB) go through a plain psum -- compression overhead isn't worth it.
    """
    # jax >= 0.6 has lax.axis_size; 0.4.x spells it psum(1, axis)
    n = jax.lax.axis_size(axis_name) if hasattr(jax.lax, "axis_size") \
        else jax.lax.psum(1, axis_name)

    def one(g):
        if g.ndim == 0 or g.size < 256:
            return jax.lax.pmean(g, axis_name)
        gf = g.astype(jnp.float32)
        q, s = _q8(gf)
        qg = jax.lax.all_gather(q, axis_name)        # (n, ...)
        sg = jax.lax.all_gather(s, axis_name)
        total = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
        return (total / n).astype(g.dtype)

    return jax.tree.map(one, tree)
