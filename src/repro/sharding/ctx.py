"""Ambient sharding context.

Model code calls ``constrain(x, role)`` at block boundaries; outside a mesh
context this is a no-op, inside one it applies the PartitionSpec registered
for that role.  This keeps model code mesh-agnostic while letting the launcher
pin the activation layout GSPMD propagates from.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules() -> Optional[Dict[str, P]]:
    return getattr(_state, "rules", None)


def _mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def sharding_rules(mesh, rules: Dict[str, P]):
    """Activate activation-sharding rules for model code under this context."""
    prev_r, prev_m = _rules(), _mesh()
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev_r, prev_m


def constrain(x, role: str):
    rules, mesh = _rules(), _mesh()
    if rules is None or mesh is None:
        return x
    spec = rules.get(role)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def current_mesh():
    """The ambient mesh, or None outside a sharding_rules context."""
    return _mesh()
