"""Partition specs for params / optimizer state / batches / caches.

Layout (DESIGN.md section 5):
* every 2-D+ weight is sharded FSDP x TP: contraction/input dim over "data"
  (ZeRO-3 resharding; GSPMD inserts the all-gathers at use), output dim over
  "model" (Megatron TP).  Row-parallel partners (wo, wd) are transposed.
* MoE expert dim shards over "data" (EP) when divisible -- expert weights
  then never gather; token routing becomes the collective instead.
* the "pod" axis is pure DP: params/opt replicated across pods, batch split.
* decode KV caches shard batch over "data" and sequence over "model"
  (flash-decode style); long_500k (batch=1) shards sequence over both.

Every dim is sharded only when divisible by the axis size; otherwise that dim
falls back to replication (never an invalid spec).  `spec_for` is
path+shape-driven so it works for any pytree the models produce.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.api import LMConfig


def _axis_size(mesh, name) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _fit(dim: int, size: int, axis: str) -> Optional[str]:
    return axis if size > 1 and dim % size == 0 else None


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def param_spec(path: str, shape: Tuple[int, ...], mesh,
               cfg: Optional[LMConfig] = None) -> P:
    """PartitionSpec for one parameter by its tree path + shape."""
    dsz, msz = _axis_size(mesh, "data"), _axis_size(mesh, "model")
    nd = len(shape)
    leaf = path.rsplit("/", 1)[-1]
    if leaf == "q":                 # int8 serving weight: use base rules
        return param_spec(path.rsplit("/", 1)[0], shape, mesh, cfg)
    if leaf == "s" and path.count("/"):  # its scale tensor: replicate
        return P()
    if nd <= 1:
        return P()

    # --- embedding / unembedding ---
    if leaf == "embed":
        return P(_fit(shape[0], msz, "model"), _fit(shape[1], dsz, "data"))
    if leaf == "unembed":
        return P(_fit(shape[0], dsz, "data"), _fit(shape[1], msz, "model"))

    stacked = "blocks/" in path or path.startswith("blocks")
    lead = 1 if stacked else 0     # skip the n_repeat stack dim

    # --- MoE expert tensors (R, E, in, out) ---
    if nd - lead == 3 and leaf in ("wg", "wu", "wd"):
        e, i, o = shape[lead], shape[lead + 1], shape[lead + 2]
        if cfg is not None and cfg.moe is not None and \
                cfg.moe.local_dispatch:
            # small-expert local dispatch: replicate over DP, TP on ff
            if leaf == "wd":
                return P(*(((None,) * lead) +
                           (None, _fit(i, msz, "model"), None)))
            return P(*(((None,) * lead) +
                       (None, None, _fit(o, msz, "model"))))
        e_ax = _fit(e, dsz, "data")
        if leaf == "wd":   # row-parallel: contraction (ff) over model
            i_ax = _fit(i, msz, "model")
            o_ax = None if e_ax else _fit(o, dsz, "data")
        else:
            i_ax = None if e_ax else _fit(i, dsz, "data")
            o_ax = _fit(o, msz, "model")
        spec = (e_ax, i_ax, o_ax)
        return P(*(((None,) * lead) + spec))

    # --- plain 2-D matmul weights (R, in, out) ---
    if nd - lead == 2:
        i, o = shape[lead], shape[lead + 1]
        if leaf in ("wo", "wd", "w_out"):      # row-parallel
            spec = (_fit(i, msz, "model"), _fit(o, dsz, "data"))
        elif leaf == "router":                 # tiny; keep E replicated
            spec = (_fit(i, dsz, "data"), None)
        else:                                  # column-parallel default
            spec = (_fit(i, dsz, "data"), _fit(o, msz, "model"))
        return P(*(((None,) * lead) + spec))

    # conv kernels (R, K, di) and other 3-D non-MoE: shard last dim on model
    if nd - lead == 2 + 1 and leaf == "conv_w":
        return P(*(((None,) * lead) + (None, _fit(shape[-1], msz, "model"))))
    if nd >= 2:
        spec = [None] * nd
        spec[-1] = _fit(shape[-1], msz, "model")
        spec[-2] = _fit(shape[-2], dsz, "data")
        return P(*spec)
    return P()


def param_specs(params_shape: Any, mesh, cfg: Optional[LMConfig] = None):
    """Pytree of PartitionSpec matching a params (shape) pytree."""
    def one(path, leaf):
        return param_spec(_path_str(path), leaf.shape, mesh, cfg)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_specs(opt_shape: Any, pspecs: Any, mesh):
    """Optimizer-state specs: moments inherit the param spec; 8-bit scale
    tensors (param.shape[:-1] + (1,)) inherit the spec minus the last axis."""
    def from_param(ps: P, shape) -> P:
        names = list(ps) + [None] * (len(shape) - len(ps))
        names = names[: len(shape)]
        # last dim of the scale tensor is 1 -> cannot stay sharded
        if shape and shape[-1] == 1:
            names[-1] = None
        return P(*names)

    m = opt_shape["m"]

    def map_state(sub):
        def one(path, leaf):
            p = _path_str(path)
            # path looks like <param_path>(/q|/s)?
            for suffix in ("/q", "/s"):
                if p.endswith(suffix):
                    p = p[: -len(suffix)]
                    break
            ps = _lookup(pspecs, p)
            return from_param(ps if ps is not None else P(), leaf.shape)
        return jax.tree_util.tree_map_with_path(one, sub)

    return {"m": map_state(opt_shape["m"]), "v": map_state(opt_shape["v"]),
            "t": P()}


def _lookup(tree, path_str: str):
    node = tree
    for k in path_str.split("/"):
        if isinstance(node, (dict,)):
            if k not in node:
                return None
            node = node[k]
        elif isinstance(node, (tuple, list)):
            node = node[int(k)]
        else:
            return None
    return node if isinstance(node, P) else None


def batch_specs(batch_shape: Any, mesh) -> Any:
    """Token batches: batch dim over (pod, data) when divisible."""
    pods = _axis_size(mesh, "pod")
    dsz = _axis_size(mesh, "data")

    def one(leaf):
        b = leaf.shape[0]
        if pods > 1 and b % (pods * dsz) == 0:
            ax = ("pod", "data")
        elif b % dsz == 0 and dsz > 1:
            ax = "data"
        else:
            ax = None
        return P(*((ax,) + (None,) * (len(leaf.shape) - 1)))

    return jax.tree.map(one, batch_shape)


def cache_specs(cache_shape: Any, cfg: LMConfig, mesh, long_context: bool):
    """Decode/prefill cache specs.

    Stacked attn caches: (R, B, S, Hkv, hd) -> B over data, S over model;
    long-context (B not divisible): S over (data, model).
    Mamba states: (R, B, H, P, N) -> B over data, H over model.
    Cross-attn:   (R, B, Si, Hkv, hd) -> B over data, Si over model.
    """
    dsz, msz = _axis_size(mesh, "data"), _axis_size(mesh, "model")

    def one(path, leaf):
        p = _path_str(path)
        nd = len(leaf.shape)
        leafname = p.rsplit("/", 1)[-1]
        if leafname in ("k", "v") and nd == 5:
            _, b, s, hkv, hd = leaf.shape
            if long_context or (dsz > 1 and b % dsz != 0):
                seq_ax = ("data", "model") if s % (dsz * msz) == 0 else \
                    _fit(s, msz, "model")
                return P(None, None, seq_ax, None, None)
            return P(None, _fit(b, dsz, "data"), _fit(s, msz, "model"),
                     None, None)
        if leafname in ("pos", "k_s", "v_s") and nd in (3, 4):
            _, b, s = leaf.shape[:3]
            rest = (None,) * (nd - 3)
            if long_context or (dsz > 1 and b % dsz != 0):
                seq_ax = ("data", "model") if s % (dsz * msz) == 0 else \
                    _fit(s, msz, "model")
                return P(None, None, seq_ax, *rest)
            return P(None, _fit(b, dsz, "data"), _fit(s, msz, "model"),
                     *rest)
        if leafname == "state" and nd == 5:    # (R, B, H, P, N)
            _, b, h, _, _ = leaf.shape
            return P(None, _fit(b, dsz, "data"), _fit(h, msz, "model"),
                     None, None)
        if leafname == "conv" and nd == 4:     # (R, B, K-1, di)
            _, b, _, di = leaf.shape
            return P(None, _fit(b, dsz, "data"), None,
                     _fit(di, msz, "model"))
        spec = [None] * nd
        if nd >= 2:
            spec[1] = _fit(leaf.shape[1], dsz, "data")
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def to_named(tree_of_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))
