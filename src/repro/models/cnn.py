"""The paper's own CNN model family (CIF10-7CNN and friends) in pure JAX.

AutoQ's experiments run on CIFAR-scale CNNs; this module provides the
faithful-reproduction substrate: a configurable conv stack with per-output-
channel quantization hooks and the QuantizableGraph extractor the agent
searches over (one LayerInfo per conv/FC layer, group_size=1 -> the paper's
exact per-channel regime).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.linear_quant import fake_quant
from repro.quant.policy import LayerInfo, QuantizableGraph


def _quant_act(x, bits):
    """Per-tensor activation fake-quant -- the paper's CNN regime (one
    dynamic scale per layer activation).  The LM stack instead quantizes
    per token (layers.maybe_quant_act): batch-coupled scales would break
    continuous-batching parity there, but the CNN search/QAT pipeline is
    calibrated -- and its accuracy-recovery tests pinned -- on the
    per-tensor quantizer."""
    if bits is None:
        return x
    return fake_quant(x, bits, axis=None)


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    img_size: int = 32
    in_channels: int = 3
    channels: Tuple[int, ...] = (32, 32, 64, 64, 128, 128, 128)  # 7 convs
    pool_after: Tuple[int, ...] = (1, 3, 5)   # maxpool after these conv idxs
    n_classes: int = 10
    kernel: int = 3


CIF10 = CNNConfig(name="cif10_7cnn")
CIF10_TINY = CNNConfig(name="cif10_tiny", img_size=16,
                       channels=(16, 16, 32, 32), pool_after=(1, 3))


class CNN:
    def __init__(self, cfg: CNNConfig):
        self.cfg = cfg

    def init(self, rng, dtype=jnp.float32):
        cfg = self.cfg
        ks = jax.random.split(rng, len(cfg.channels) + 1)
        params = {}
        cin = cfg.in_channels
        for i, cout in enumerate(cfg.channels):
            fan_in = cfg.kernel * cfg.kernel * cin
            params[f"conv{i}"] = {
                "w": (jax.random.normal(ks[i], (cfg.kernel, cfg.kernel, cin,
                                                cout)) *
                      np.sqrt(2.0 / fan_in)).astype(dtype),
                "b": jnp.zeros((cout,), dtype),
            }
            cin = cout
        params["fc"] = {
            "w": (jax.random.normal(ks[-1], (cin, cfg.n_classes)) *
                  np.sqrt(1.0 / cin)).astype(dtype),
            "b": jnp.zeros((cfg.n_classes,), dtype),
        }
        return params

    def apply(self, params, x, act_bits=None):
        """x: (B, H, W, C).  act_bits: None or dict layer-name -> scalar."""
        cfg = self.cfg

        def ab(name):
            return None if act_bits is None else act_bits.get(name)

        for i in range(len(cfg.channels)):
            x = _quant_act(x, ab(f"conv{i}"))
            p = params[f"conv{i}"]
            x = jax.lax.conv_general_dilated(
                x, p["w"], window_strides=(1, 1), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(x + p["b"])
            if i in cfg.pool_after:
                x = jax.lax.reduce_window(
                    x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                    "VALID")
        x = jnp.mean(x, axis=(1, 2))                 # global average pool
        x = _quant_act(x, ab("fc"))
        return x @ params["fc"]["w"] + params["fc"]["b"]

    def loss(self, params, batch, act_bits=None):
        logits = self.apply(params, batch["x"], act_bits=act_bits)
        labels = batch["y"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold)

    def accuracy(self, params, batch, act_bits=None):
        logits = self.apply(params, batch["x"], act_bits=act_bits)
        return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(
            jnp.float32))

    def graph(self) -> QuantizableGraph:
        """Per-channel (group_size=1) quantizable graph with MAC counts."""
        cfg = self.cfg
        layers = []
        hw = cfg.img_size
        cin = cfg.in_channels
        for i, cout in enumerate(cfg.channels):
            macs = hw * hw * cfg.kernel * cfg.kernel * cin * cout
            layers.append(LayerInfo(
                name=f"conv{i}", kind="conv", c_in=cin, c_out=cout,
                k=cfg.kernel, stride=1, macs=float(macs),
                numel=cfg.kernel * cfg.kernel * cin * cout,
                param_path=(f"conv{i}", "w"), channel_axis=3, n_groups=cout))
            if i in cfg.pool_after:
                hw //= 2
            cin = cout
        layers.append(LayerInfo(
            name="fc", kind="linear", c_in=cin, c_out=cfg.n_classes, k=1,
            stride=1, macs=float(cin * cfg.n_classes),
            numel=cin * cfg.n_classes, param_path=("fc", "w"),
            channel_axis=1, n_groups=cfg.n_classes))
        return QuantizableGraph(layers=layers)
