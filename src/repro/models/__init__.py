"""Model zoo: config-driven decoder LMs + the paper's CNN family."""
from repro.models.api import (BlockDef, LMConfig, MoECfg, SSMCfg, ShapeCfg,
                              SHAPES, shape_by_name)
from repro.models.cnn import CNN, CNNConfig, CIF10, CIF10_TINY
from repro.models.transformer import LM

__all__ = ["BlockDef", "LMConfig", "MoECfg", "SSMCfg", "ShapeCfg", "SHAPES",
           "shape_by_name", "CNN", "CNNConfig", "CIF10", "CIF10_TINY", "LM"]
