"""Mamba2 (SSD, state-space duality) block in JAX.

Training/prefill uses the SSD *block decomposition*: within chunks of length Q
the recurrence is evaluated as attention-like matmuls (MXU-friendly), across
chunks a lax.scan carries the (H, P, N) state.  Decode is the O(1) single-step
state update.  This follows arXiv:2405.21060 section 6; simplifications
(single B/C group, conv on x only) are noted in DESIGN.md.

Shapes: B batch, S seq, H heads, P head_dim, N d_state, Q chunk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.api import SSMCfg
from repro.models.layers import deq, rmsnorm, wcol, wrow


def init_mamba_params(rng, d_model: int, cfg: SSMCfg, dtype=jnp.float32):
    di = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    N = cfg.d_state
    ks = jax.random.split(rng, 8)

    def lin(key, fan_in, shape):
        return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)

    return {
        "w_xz": lin(ks[0], d_model, (d_model, 2 * di)),
        "w_bc": lin(ks[1], d_model, (d_model, 2 * N)),
        "w_dt": lin(ks[2], d_model, (d_model, H)),
        "dt_bias": jnp.zeros((H,), dtype),
        "A_log": jnp.zeros((H,), dtype),                    # A = -exp(A_log) = -1
        "D": jnp.ones((H,), dtype),
        "conv_w": lin(ks[3], cfg.d_conv, (cfg.d_conv, di)),
        "conv_b": jnp.zeros((di,), dtype),
        "norm_w": jnp.zeros((di,), dtype),
        "w_out": lin(ks[4], di, (di, d_model)),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: (B, S, di); w: (K, di)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _ssd_chunk_scan(xh, Bm, Cm, dt, A, chunk: int):
    """Chunked SSD scan.

    xh: (B, S, H, P); Bm, Cm: (B, S, N); dt: (B, S, H); A: (H,) negative.
    Returns (y: (B, S, H, P), final_state: (B, H, P, N)).
    """
    Bb, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:  # pad tail with dt=0 steps: decay=1, no state update
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    # log decay per step: log a_t = dt_t * A  (A < 0)
    la = dt * A                                              # (B, S, H)
    xc = xh.reshape(Bb, nc, Q, H, P)
    Bc = Bm.reshape(Bb, nc, Q, N)
    Cc = Cm.reshape(Bb, nc, Q, N)
    dtc = dt.reshape(Bb, nc, Q, H)
    lac = la.reshape(Bb, nc, Q, H)

    def body(state, xs):
        xq, bq, cq, dq, lq = xs                              # leading dim B
        l_cum = jnp.cumsum(lq, axis=1)                       # (B, Q, H)
        l_tot = l_cum[:, -1]                                 # (B, H)

        # inter-chunk: contribution of the carried state.
        dec_in = jnp.exp(l_cum)                              # (B, Q, H)
        y_inter = jnp.einsum("bqn,bhpn->bqhp", cq, state) * dec_in[..., None]

        # intra-chunk: masked decay kernel M[t, s] = e^{l_t - l_s} dt_s (C_t.B_s)
        rel = l_cum[:, :, None, :] - l_cum[:, None, :, :]    # (B, Qt, Qs, H)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        rel = jnp.where(mask[None, :, :, None], rel, -jnp.inf)
        cb = jnp.einsum("btn,bsn->bts", cq, bq)              # (B, Qt, Qs)
        M = jnp.exp(rel) * cb[..., None] * dq[:, None, :, :]  # (B,Qt,Qs,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", M, xq)

        # state update
        dec_out = jnp.exp(l_tot[:, None, :] - l_cum)         # (B, Q, H)
        upd = jnp.einsum("bqh,bqhp,bqn->bhpn", dec_out * dq, xq, bq)
        new_state = state * jnp.exp(l_tot)[..., None, None] + upd
        return new_state, y_inter + y_intra

    s0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    xs = tuple(t.transpose(1, 0, *range(2, t.ndim))
               for t in (xc, Bc, Cc, dtc, lac))
    final, yc = jax.lax.scan(body, s0, xs)
    y = yc.transpose(1, 0, 2, 3, 4).reshape(Bb, S, H, P)
    return y[:, :S_orig], final


def mamba_forward(params, x, cfg: SSMCfg, d_model: int):
    """Full-sequence forward.  x: (B, S, d).  Returns (y, final_cache)."""
    Bb, S, _ = x.shape
    di = cfg.d_inner(d_model)
    H, P, N = cfg.n_heads(d_model), cfg.head_dim, cfg.d_state

    xz = x @ wcol(params["w_xz"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = jax.nn.silu(_causal_conv(xi, params["conv_w"], params["conv_b"]))
    bc = x @ wcol(params["w_bc"])
    Bm, Cm = jnp.split(bc.astype(jnp.float32), 2, axis=-1)
    dt = jax.nn.softplus((x @ wcol(params["w_dt"])).astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    xh = xi.astype(jnp.float32).reshape(Bb, S, H, P)
    y, state = _ssd_chunk_scan(xh, Bm, Cm, dt, A, cfg.chunk)
    y = y + params["D"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(Bb, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_w"])
    out = y @ wrow(params["w_out"])
    conv_cache = _last_conv_window(xz, cfg)
    return out, {"state": state, "conv": conv_cache}


def _last_conv_window(xz, cfg: SSMCfg):
    """(d_conv-1) trailing pre-conv activations, for decode continuation."""
    di2 = xz.shape[-1]
    xi = xz[..., : di2 // 2]
    K = cfg.d_conv
    return xi[:, -(K - 1):, :] if xz.shape[1] >= K - 1 else \
        jnp.pad(xi, ((0, 0), (K - 1 - xz.shape[1], 0), (0, 0)))


def init_mamba_cache(batch: int, d_model: int, cfg: SSMCfg, dtype=jnp.float32):
    H, P, N = cfg.n_heads(d_model), cfg.head_dim, cfg.d_state
    di = cfg.d_inner(d_model)
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
    }


def mamba_decode_step(params, x, cache, cfg: SSMCfg, d_model: int):
    """Single-token decode.  x: (B, 1, d).  Returns (y: (B, 1, d), cache)."""
    Bb = x.shape[0]
    di = cfg.d_inner(d_model)
    H, P, N = cfg.n_heads(d_model), cfg.head_dim, cfg.d_state

    xz = x @ wcol(params["w_xz"])
    xi, z = jnp.split(xz, 2, axis=-1)                        # (B, 1, di)
    win = jnp.concatenate([cache["conv"], xi], axis=1)       # (B, K, di)
    conv = (win * params["conv_w"][None]).sum(axis=1, keepdims=True) \
        + params["conv_b"]
    xi = jax.nn.silu(conv)

    bc = (x @ wcol(params["w_bc"])).astype(jnp.float32)
    Bm, Cm = jnp.split(bc[:, 0], 2, axis=-1)                 # (B, N)
    dt = jax.nn.softplus((x @ wcol(params["w_dt"])).astype(jnp.float32)[:, 0]
                         + params["dt_bias"].astype(jnp.float32))  # (B, H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                                      # (B, H)

    xh = xi.astype(jnp.float32).reshape(Bb, H, P)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bm)
    state = cache["state"] * a[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm, state)
    y = y + params["D"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(Bb, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_w"])
    out = y @ wrow(params["w_out"])
    new_cache = {"state": state, "conv": win[:, 1:, :]}
    return out, new_cache
