"""Config-driven decoder LM covering all assigned architecture families.

One implementation assembles: GQA attention (RoPE, sliding window, softcap),
SwiGLU / MoE FFNs, Mamba2 (SSD) blocks, and cross-attention (VLM) blocks from
an :class:`LMConfig` periodic pattern.  The stack lowers as ``lax.scan`` over
pattern repeats (stacked parameters, leading axis n_repeat), so HLO size is
O(pattern period), not O(depth).

Entry points: ``apply`` (full-sequence train forward), ``prefill`` (forward +
cache fill, last-token logits), ``decode_step`` (single token with cache),
``decode_step_paged`` (paged single token), and ``model_step`` -- the
serving engine's unified token-budget step, where every row is a prompt
chunk or a decode token written straight into block-table pages.
Kernel-wise quantization hooks: weights are fake-quantized outside the forward
via ``quant.apply_policy_to_params``; activations via ``act_bits``, one scalar
per (repeat, pattern-position) block.

KV-cache convention: unwritten slots carry position ``POS_SENTINEL`` (int32
max) so the causal mask ``kv_pos <= q_pos`` rejects them without a separate
validity length.  ``local_attn`` blocks use a ring buffer of size ``window``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ssm as ssm_mod
from repro.models.api import BlockDef, LMConfig
from repro.models.layers import (attention, deq, maybe_quant_act, moe_ffn,
                                 paged_attention, rmsnorm, rope, softcap,
                                 swiglu, wcol, wrow)
from repro.quant.policy import LayerInfo, QuantizableGraph
from repro.sharding.ctx import constrain

POS_SENTINEL = np.iinfo(np.int32).max
# physical page 0 of every paged pool is the never-allocated trash page
# (serve/paged_kv.py re-exports this and owns the lifecycle invariants;
# defined here, like POS_SENTINEL, because the paged write path below must
# route sentinel lanes to it without importing the serve layer)
TRASH_PAGE = 0


def _lin_init(key, fan_in, shape, dtype):
    return (jax.random.normal(key, shape) / np.sqrt(fan_in)).astype(dtype)


# ----------------------------------------------------- quantized KV caching
def _kv_quant(x):
    """(B, S, Hkv, hd) -> (int8 values, f32 scale (B, S, Hkv))."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    s = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / s[..., None]), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def _kv_deq(cache, key):
    kq = cache[key]
    if kq.dtype == jnp.int8:
        return kq.astype(jnp.float32) * cache[key + "_s"][..., None]
    return kq


def _kv_write(cache, k, v, pos, slot):
    """Write (k, v, pos) into the cache window starting at `slot`,
    quantizing per (position, head) when the cache stores int8."""
    out = dict(cache)
    for key, val in (("k", k), ("v", v)):
        if cache[key].dtype == jnp.int8:
            q, s = _kv_quant(val)
            out[key] = jax.lax.dynamic_update_slice(cache[key], q,
                                                    (0, slot, 0, 0))
            out[key + "_s"] = jax.lax.dynamic_update_slice(
                cache[key + "_s"], s, (0, slot, 0))
        else:
            out[key] = jax.lax.dynamic_update_slice(
                cache[key], val.astype(cache[key].dtype), (0, slot, 0, 0))
    out["pos"] = jax.lax.dynamic_update_slice(cache["pos"],
                                              pos.astype(jnp.int32),
                                              (0, slot))
    return out


def _kv_store_full(cache, k, v):
    """Cross-attention memory: overwrite the whole (fixed-length) cache."""
    out = dict(cache)
    for key, val in (("k", k), ("v", v)):
        if cache[key].dtype == jnp.int8:
            q, s = _kv_quant(val)
            out[key], out[key + "_s"] = q, s
        else:
            out[key] = val.astype(cache[key].dtype)
    return out


class LM:
    """Stateless model object: config + pure init/apply functions."""

    def __init__(self, cfg: LMConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def _init_block(self, rng, bdef: BlockDef, dtype):
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.hdim
        Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
        ks = iter(jax.random.split(rng, 16))
        p: Dict[str, Any] = {"norm": jnp.zeros((d,), dtype)}
        if bdef.kind in ("attn", "local_attn", "cross_attn"):
            p["wq"] = _lin_init(next(ks), d, (d, Hq * hd), dtype)
            p["wk"] = _lin_init(next(ks), d, (d, Hkv * hd), dtype)
            p["wv"] = _lin_init(next(ks), d, (d, Hkv * hd), dtype)
            p["wo"] = _lin_init(next(ks), Hq * hd, (Hq * hd, d), dtype)
        elif bdef.kind == "mamba":
            p["mamba"] = ssm_mod.init_mamba_params(next(ks), d, cfg.ssm, dtype)
        else:
            raise ValueError(bdef.kind)
        if bdef.has_ffn:
            p["ffn_norm"] = jnp.zeros((d,), dtype)
            if bdef.use_moe:
                m = cfg.moe
                ep = m.n_experts_phys
                p["router"] = _lin_init(next(ks), d, (d, m.n_experts), dtype)
                p["wg"] = _lin_init(next(ks), d, (ep, d, m.d_ff), dtype)
                p["wu"] = _lin_init(next(ks), d, (ep, d, m.d_ff), dtype)
                p["wd"] = _lin_init(next(ks), m.d_ff,
                                    (ep, m.d_ff, d), dtype)
            else:
                p["wg"] = _lin_init(next(ks), d, (d, cfg.d_ff), dtype)
                p["wu"] = _lin_init(next(ks), d, (d, cfg.d_ff), dtype)
                p["wd"] = _lin_init(next(ks), cfg.d_ff, (cfg.d_ff, d), dtype)
        return p

    def init(self, rng, dtype=jnp.float32):
        cfg = self.cfg
        keys = jax.random.split(rng, len(cfg.pattern) + 2)
        blocks = []
        for p_idx, bdef in enumerate(cfg.pattern):
            reps = jax.random.split(keys[p_idx], cfg.n_repeat)
            stacked = jax.vmap(
                lambda k, b=bdef, dt=dtype: self._init_block(k, b, dt))(reps)
            blocks.append(stacked)
        params = {
            "blocks": tuple(blocks),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
            "unembed": _lin_init(keys[-1], cfg.d_model,
                                 (cfg.d_model, cfg.vocab_padded), dtype),
        }
        if cfg.frontend != "audio_stub":
            params["embed"] = (jax.random.normal(
                keys[-2], (cfg.vocab_padded, cfg.d_model)) /
                np.sqrt(cfg.d_model)).astype(dtype)
        return params

    # ---------------------------------------------------------------- blocks
    def _attn_block(self, bp, bdef, x, *, q_pos, mode, img_embeds=None,
                    cache=None, write_pos=None, act_bits=None,
                    block_tables=None, attn_impl=None):
        """Self- or cross-attention + residual.  Returns (x, new_cache).

        block_tables (decode only): (B, nb) int32 physical page ids -- the
        cache entry is then a paged pool (P, page_size, Hkv, hd) shared by
        the batch, written through the table and attended per sequence
        (``write_pos`` is per-sequence (B,) in that mode).  An int8 pool
        (``init_paged_cache(kv_bits=8)``) quantizes the write and carries
        per-(slot, head) scale pages.  ``attn_impl`` selects the attention
        backend (layers.ATTN_IMPLS; None -> "ref"); it must be static."""
        cfg = self.cfg
        B, S, _ = x.shape
        Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hdim
        h = rmsnorm(x, bp["norm"], cfg.norm_eps)
        h = maybe_quant_act(h, act_bits)
        q = (h @ wcol(bp["wq"])).reshape(B, S, Hq, hd)
        new_cache = cache

        if bdef.kind == "cross_attn":
            causal, window = False, None
            if mode == "decode":
                k, v = _kv_deq(cache, "k"), _kv_deq(cache, "v")
            else:
                Si = img_embeds.shape[1]
                k = (img_embeds @ wcol(bp["wk"])).reshape(B, Si, Hkv, hd)
                v = (img_embeds @ wcol(bp["wv"])).reshape(B, Si, Hkv, hd)
                if cache is not None:
                    new_cache = _kv_store_full(cache, k, v)
            kv_pos = jnp.zeros((B, k.shape[1]), jnp.int32)
        else:
            causal = True
            window = cfg.window if bdef.kind == "local_attn" else None
            k = (h @ wcol(bp["wk"])).reshape(B, S, Hkv, hd)
            v = (h @ wcol(bp["wv"])).reshape(B, S, Hkv, hd)
            q = rope(q, q_pos, cfg.rope_theta)
            k = rope(k, q_pos, cfg.rope_theta)
            kv_pos = q_pos
            if cache is not None:
                if block_tables is not None:   # paged write+attend (S >= 1:
                    # one decode token or a k-token prompt chunk per row)
                    ps = cache["k"].shape[-3]
                    nb = block_tables.shape[1]
                    wp = write_pos if write_pos.ndim == 2 \
                        else write_pos[:, None]            # (B, S)
                    # sentinel lanes (idle decode slots, chunk padding)
                    # route to the trash page *explicitly*: an active row's
                    # clipped block index would land in one of its own real
                    # pages and corrupt a live KV slot
                    blk = jnp.minimum((wp // ps).astype(jnp.int32), nb - 1)
                    phys = jnp.take_along_axis(block_tables, blk, axis=1)
                    phys = jnp.where(wp == POS_SENTINEL, TRASH_PAGE, phys)
                    fp = phys.reshape(-1)                  # flat (B*S,)
                    fs = (wp % ps).reshape(-1)
                    new_cache = dict(cache)
                    if cache["k"].dtype == jnp.int8:   # quantized page write
                        for key, val in (("k", k), ("v", v)):
                            qv, sv = _kv_quant(val)
                            new_cache[key] = cache[key].at[fp, fs].set(
                                qv.reshape((-1,) + qv.shape[2:]))
                            new_cache[key + "_s"] = \
                                cache[key + "_s"].at[fp, fs].set(
                                    sv.reshape((-1,) + sv.shape[2:]))
                    else:
                        new_cache["k"] = cache["k"].at[fp, fs].set(
                            k.reshape((-1,) + k.shape[2:])
                            .astype(cache["k"].dtype))
                        new_cache["v"] = cache["v"].at[fp, fs].set(
                            v.reshape((-1,) + v.shape[2:])
                            .astype(cache["v"].dtype))
                    new_cache["pos"] = cache["pos"].at[fp, fs].set(
                        wp.reshape(-1).astype(jnp.int32))
                    out = paged_attention(
                        q, new_cache["k"], new_cache["v"], new_cache["pos"],
                        block_tables, q_pos=q_pos, causal=causal,
                        window=window, attn_cap=cfg.attn_softcap,
                        k_scale_pages=new_cache.get("k_s"),
                        v_scale_pages=new_cache.get("v_s"), impl=attn_impl)
                    x = x + out.reshape(B, S, Hq * hd) @ wrow(bp["wo"])
                    return x, new_cache
                W = cache["k"].shape[1]
                if mode == "decode":
                    slot = write_pos % W if bdef.kind == "local_attn" \
                        else write_pos
                    new_cache = _kv_write(cache, k, v, q_pos, slot)
                    k = _kv_deq(new_cache, "k")
                    v = _kv_deq(new_cache, "v")
                    kv_pos = new_cache["pos"]
                else:  # prefill: write last W positions, ring-aligned
                    kw, vw, pw = k, v, q_pos
                    if W < S:
                        # keep positions S-W..S-1, rolled so position p sits
                        # at its ring slot p % W -- decode's overwrite at
                        # write_pos % W then evicts exactly the oldest
                        # position (evicting an arbitrary one would drop a
                        # still-in-window entry, diverging from the paged
                        # and full-forward paths)
                        sh = (S - W) % W
                        kw = jnp.roll(k[:, -W:], sh, axis=1)
                        vw = jnp.roll(v[:, -W:], sh, axis=1)
                        pw = jnp.roll(q_pos[:, -W:], sh, axis=1)
                    new_cache = _kv_write(cache, kw, vw, pw, 0)
                    if cache["k"].dtype == jnp.int8:
                        # serve-consistent numerics: prompt tokens attend
                        # the int8 round trip of the in-flight K/V -- the
                        # exact values decode reads back from the cache and
                        # the chunked paged path reads from int8 pages
                        # (per-position scales, so the round trip covers
                        # even ring-evicted positions identically)
                        kq, ks = _kv_quant(k)
                        k = kq.astype(jnp.float32) * ks[..., None]
                        vq, vs = _kv_quant(v)
                        v = vq.astype(jnp.float32) * vs[..., None]
                    elif cache["k"].dtype != k.dtype:
                        # same contract for narrow fp caches (bf16): attend
                        # the cache-dtype round trip the chunked paged path
                        # reads back, keeping run() == generate() parity
                        # independent of cache_dtype
                        k = k.astype(cache["k"].dtype).astype(k.dtype)
                        v = v.astype(cache["v"].dtype).astype(v.dtype)
        chunk = k.shape[1] if S == 1 else 1024
        out = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal,
                        window=window, attn_cap=cfg.attn_softcap, chunk=chunk,
                        impl=attn_impl)
        x = x + out.reshape(B, S, Hq * hd) @ wrow(bp["wo"])
        return x, new_cache

    def _ffn(self, bp, bdef, x, act_bits=None):
        cfg = self.cfg
        h = rmsnorm(x, bp["ffn_norm"], cfg.norm_eps)
        if bdef.use_moe:
            m = cfg.moe
            out, probs = moe_ffn(h, bp, n_experts=m.n_experts, top_k=m.top_k,
                                 capacity_factor=m.capacity_factor,
                                 act_bits=act_bits,
                                 local_dispatch=m.local_dispatch)
            frac = jnp.mean(probs, axis=0)
            aux = m.n_experts * jnp.sum(frac * frac)
            return x + out, aux
        return x + swiglu(h, bp, act_bits=act_bits), jnp.float32(0.0)

    def _apply_block(self, bp, bdef: BlockDef, x, *, q_pos, mode,
                     img_embeds=None, cache=None, write_pos=None,
                     act_bits=None, block_tables=None, attn_impl=None):
        if bdef.kind == "mamba":
            h = rmsnorm(x, bp["norm"], self.cfg.norm_eps)
            h = maybe_quant_act(h, act_bits)
            if mode == "decode":
                out, mcache = ssm_mod.mamba_decode_step(
                    bp["mamba"], h, cache, self.cfg.ssm, self.cfg.d_model)
            else:
                out, mcache = ssm_mod.mamba_forward(
                    bp["mamba"], h, self.cfg.ssm, self.cfg.d_model)
                if cache is not None:
                    mcache = jax.tree.map(lambda a, c: a.astype(c.dtype),
                                          mcache, cache)
            x = x + out
            new_cache = mcache
        else:
            x, new_cache = self._attn_block(
                bp, bdef, x, q_pos=q_pos, mode=mode, img_embeds=img_embeds,
                cache=cache, write_pos=write_pos, act_bits=act_bits,
                block_tables=None if bdef.kind == "cross_attn"
                else block_tables, attn_impl=attn_impl)
        aux = jnp.float32(0.0)
        if bdef.has_ffn:
            x, aux = self._ffn(bp, bdef, x, act_bits=act_bits)
        return x, new_cache, aux

    # --------------------------------------------------------------- helpers
    def _embed_tokens(self, params, tokens):
        emb = params["embed"]
        if isinstance(emb, dict):          # int8 rows + per-row scale
            return jnp.take(emb["q"], tokens, axis=0).astype(
                emb["s"].dtype) * jnp.take(emb["s"], tokens, axis=0)
        return jnp.take(emb, tokens, axis=0)

    def _embed(self, params, batch):
        if self.cfg.frontend == "audio_stub":
            x = batch["embeds"]
        else:
            x = self._embed_tokens(params, batch["tokens"])
        return constrain(x, "hidden")

    def logits_of(self, params, x):
        cfg = self.cfg
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        lg = x @ wcol(params["unembed"])
        lg = constrain(lg, "logits")
        lg = softcap(lg, cfg.logit_softcap)
        if cfg.vocab_padded != cfg.vocab:   # mask padded vocab entries
            valid = jnp.arange(cfg.vocab_padded) < cfg.vocab
            lg = jnp.where(valid, lg, jnp.asarray(-1e30, lg.dtype))
        return lg

    # ------------------------------------------------- int8 serving weights
    def quantize_params_int8(self, params):
        """Deployment transform: every matmul weight -> {"q": int8, "s"}.

        Scales are per output channel (last axis), reducing over the
        contraction axis; embedding rows get per-row scales.  Norms, biases
        and scalar leaves stay full precision.  The forward dequantizes at
        use (layers.deq), which fuses into the consuming matmul on TPU --
        HBM weight traffic drops to 1 byte/element.
        """
        MATMUL_LEAVES = {"wq", "wk", "wv", "wo", "wg", "wu", "wd", "router",
                         "w_xz", "w_bc", "w_dt", "w_out", "embed", "unembed"}

        def one(path, w):
            name = str(path[-1])
            if name not in MATMUL_LEAVES or w.ndim < 2 or \
                    w.dtype == jnp.int8:
                return w
            if name == "embed":
                red = (1,)                         # per-row (vocab) scale
            else:
                red = (w.ndim - 2,)                # over contraction axis
            amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=red,
                           keepdims=True)
            s = jnp.where(amax > 0, amax / 127.0, 1.0)
            q = jnp.clip(jnp.round(w.astype(jnp.float32) / s), -127,
                         127).astype(jnp.int8)
            return {"q": q, "s": s.astype(jnp.float32)}

        flat = jax.tree_util.tree_map_with_path(
            lambda p, w: one([getattr(k, "key", getattr(k, "idx", "?"))
                              for k in p], w), params)
        return flat

    # ---------------------------------------------------------------- train
    def apply(self, params, batch, act_bits: Optional[jnp.ndarray] = None,
              remat: bool = False):
        """Full-sequence forward.  Returns (logits, aux_loss).

        act_bits: optional (n_repeat, len(pattern)) activation QBN array.
        remat: rematerialize each pattern repeat in the backward pass
        (activation memory O(1) in depth; standard at 70B+ scale).
        """
        cfg = self.cfg
        x = self._embed(params, batch)
        B, S, _ = x.shape
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        img_embeds = batch.get("img_embeds")

        def repeat_body(carry, xs):
            x, aux = carry
            blocks_slice, ab_slice = xs
            for p_idx, bdef in enumerate(cfg.pattern):
                ab = None if ab_slice is None else ab_slice[p_idx]
                x, _, a = self._apply_block(
                    blocks_slice[p_idx], bdef, x, q_pos=q_pos, mode="train",
                    img_embeds=img_embeds, act_bits=ab)
                x = constrain(x, "hidden")
                aux = aux + a
            return (x, aux), None

        if act_bits is None:
            body = lambda c, bs: repeat_body(c, (bs, None))
            xs = params["blocks"]
        else:
            body, xs = repeat_body, (params["blocks"], act_bits)
        if remat:
            # True -> save nothing per repeat; "dots" -> keep matmul outputs
            # (incl. FSDP-gathered weight products: no re-gather in backward)
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if remat == "dots" else None)
            body = jax.checkpoint(body, policy=policy)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
        return self.logits_of(params, x), aux

    def loss(self, params, batch, act_bits=None, remat: bool = False):
        logits, aux = self.apply(params, batch, act_bits=act_bits,
                                 remat=remat)
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), jnp.maximum(labels, 0)[..., None],
            axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        nll = jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
        return nll + 0.01 * aux

    # ---------------------------------------------------------------- caches
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   kv_bits: Optional[int] = None):
        """Per-pattern-position stacked cache pytree (leading dim n_repeat).

        kv_bits=8 stores K/V int8 with per-(position, head) scales -- halving
        the dominant HBM term of long-context decode (DESIGN.md section 3)."""
        cfg = self.cfg
        kv_dt = jnp.int8 if kv_bits == 8 else dtype

        def kv_entry(b, s):
            one = {
                "k": jnp.zeros((b, s, cfg.n_kv_heads, cfg.hdim), kv_dt),
                "v": jnp.zeros((b, s, cfg.n_kv_heads, cfg.hdim), kv_dt),
            }
            if kv_bits == 8:
                one["k_s"] = jnp.ones((b, s, cfg.n_kv_heads), jnp.float32)
                one["v_s"] = jnp.ones((b, s, cfg.n_kv_heads), jnp.float32)
            return one

        caches = []
        for bdef in cfg.pattern:
            if bdef.kind == "mamba":
                one = ssm_mod.init_mamba_cache(batch, cfg.d_model, cfg.ssm,
                                               dtype)
            elif bdef.kind == "cross_attn":
                one = kv_entry(batch, cfg.n_img_tokens)
            else:
                W = max_len if (bdef.kind != "local_attn" or cfg.window is None) \
                    else min(max_len, cfg.window)
                one = kv_entry(batch, W)
                one["pos"] = jnp.full((batch, W), POS_SENTINEL, jnp.int32)
            stacked = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (cfg.n_repeat,) + a.shape),
                one)
            caches.append(stacked)
        return tuple(caches)

    def init_paged_cache(self, n_slots: int, num_pages: int, page_size: int,
                         dtype=jnp.bfloat16, kv_bits: Optional[int] = None,
                         n_repeat: Optional[int] = None):
        """Paged decode cache for the continuous-batching engine.

        Per pattern position (stacked over n_repeat like ``init_cache``),
        keyed by ``cfg.cache_kinds()``:

        * ``"paged"`` (attn / local_attn): a pool of ``num_pages`` physical
          pages of ``page_size`` KV slots shared by all sequences --
          ``{"k","v": (R, P, ps, Hkv, hd), "pos": (R, P, ps) int32}``.
          ``pos`` starts at ``POS_SENTINEL`` so unwritten slots are masked;
          page 0 is the trash page (serve/paged_kv.py owns the lifecycle).
        * ``"memory"`` (cross_attn) / ``"state"`` (mamba): dense per-slot
          caches with batch axis ``n_slots``, exactly the single-batch
          layouts, since neither grows with decoded length.

        ``kv_bits=8`` stores K/V pages int8 with one scale page per KV page
        (``"k_s","v_s": (R, P, ps, Hkv) f32``, per-(slot, head) scales) --
        the same quantizer as the dense cache (``_kv_quant``), so paged
        serving is bit-identical to dense int8 decode; the Pallas decode
        kernel dequantizes the pages in VMEM.

        ``n_repeat`` overrides the stack depth (default ``cfg.n_repeat``):
        the speculative engine's shallow-prefix *draft* cache stacks only
        the first ``draft_layers`` repeats (:meth:`draft_prefix_params`),
        sharing the main stream's block tables.
        """
        cfg = self.cfg
        R = cfg.n_repeat if n_repeat is None else n_repeat
        if not 1 <= R <= cfg.n_repeat:
            raise ValueError(f"n_repeat override {R} outside 1.."
                             f"{cfg.n_repeat}")
        kv_dt = jnp.int8 if kv_bits == 8 else dtype

        def kv_pages():
            one = {
                "k": jnp.zeros((num_pages, page_size, cfg.n_kv_heads,
                                cfg.hdim), kv_dt),
                "v": jnp.zeros((num_pages, page_size, cfg.n_kv_heads,
                                cfg.hdim), kv_dt),
                "pos": jnp.full((num_pages, page_size), POS_SENTINEL,
                                jnp.int32),
            }
            if kv_bits == 8:
                one["k_s"] = jnp.ones((num_pages, page_size,
                                       cfg.n_kv_heads), jnp.float32)
                one["v_s"] = jnp.ones((num_pages, page_size,
                                       cfg.n_kv_heads), jnp.float32)
            return one

        caches = []
        for bdef, kind in zip(cfg.pattern, cfg.cache_kinds()):
            if kind == "state":
                one = ssm_mod.init_mamba_cache(n_slots, cfg.d_model, cfg.ssm,
                                               dtype)
            elif kind == "memory":
                one = {
                    "k": jnp.zeros((n_slots, cfg.n_img_tokens,
                                    cfg.n_kv_heads, cfg.hdim), dtype),
                    "v": jnp.zeros((n_slots, cfg.n_img_tokens,
                                    cfg.n_kv_heads, cfg.hdim), dtype),
                }
            else:
                one = kv_pages()
            stacked = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (R,) + a.shape), one)
            caches.append(stacked)
        return tuple(caches)

    # -------------------------------------------------- draft-prefix view
    def draft_prefix_params(self, params, draft_layers: int):
        """Shallow self-draft view: the first ``draft_layers`` pattern
        repeats of ``params``, sharing embed/final_norm/unembed.

        The stacked-block layout makes a depth-truncated model a pure
        *slice*: every leaf of ``params["blocks"]`` carries the repeat
        stack as its leading axis (including :class:`PackedWeight`
        children, whose static aux -- bucket membership -- is R-invariant
        by construction), so ``leaf[:draft_layers]`` is a valid parameter
        pytree for the same entry points.  ``model_step`` then runs the
        draft exactly like the target, against a draft cache stacked to
        the same depth (``init_paged_cache(n_repeat=draft_layers)``).
        Used by the speculative serving loop (docs/speculative.md); with
        ``draft_layers == n_repeat`` the draft *is* the target (acceptance
        1.0 -- the parity-bench sanity ceiling).
        """
        if not 1 <= draft_layers <= self.cfg.n_repeat:
            raise ValueError(
                f"draft_layers={draft_layers} outside 1..{self.cfg.n_repeat}"
                f" (cfg.n_repeat)")
        blocks = jax.tree.map(lambda a: a[:draft_layers], params["blocks"])
        return {**params, "blocks": blocks}

    # ------------------------------------------------------------ prefill
    def prefill(self, params, batch, cache, act_bits=None, attn_impl=None):
        """Run the prompt, fill the cache, return last-token logits.

        act_bits: optional (n_repeat, len(pattern)) activation QBN array --
        the same per-block hook ``apply`` takes, so a searched policy's
        activation bits follow the model into serving.  attn_impl: static
        attention backend selector (layers.ATTN_IMPLS)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        B, S, _ = x.shape
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        img_embeds = batch.get("img_embeds")

        def repeat_body(x, xs):
            blocks_slice, cache_slice, ab_slice = xs
            new_slices = []
            for p_idx, bdef in enumerate(cfg.pattern):
                ab = None if ab_slice is None else ab_slice[p_idx]
                x, nc, _ = self._apply_block(
                    blocks_slice[p_idx], bdef, x, q_pos=q_pos, mode="prefill",
                    img_embeds=img_embeds, cache=cache_slice[p_idx],
                    act_bits=ab, attn_impl=attn_impl)
                x = constrain(x, "hidden")
                new_slices.append(nc)
            return x, tuple(new_slices)

        body, xs = self._with_act_bits(repeat_body, params, cache, act_bits)
        x, new_cache = jax.lax.scan(body, x, xs)
        logits = self.logits_of(params, x[:, -1:, :])
        return logits, new_cache

    @staticmethod
    def _with_act_bits(repeat_body, params, cache, act_bits):
        """Scan inputs for a cached step, with or without the act-QBN rows."""
        if act_bits is None:
            return (lambda c, xs: repeat_body(c, xs + (None,)),
                    (params["blocks"], cache))
        return repeat_body, (params["blocks"], cache, act_bits)

    # ------------------------------------------------------------- decode
    def decode_step(self, params, tokens, cache, pos, act_bits=None,
                    attn_impl=None):
        """One decode step.  tokens: (B, 1) int32 (or (B, 1, d) embeds for
        audio_stub); pos: scalar int32.  act_bits / attn_impl as in
        :meth:`prefill`.  Returns (logits, new_cache)."""
        cfg = self.cfg
        if cfg.frontend == "audio_stub":
            x = tokens
        else:
            x = self._embed_tokens(params, tokens)
        x = constrain(x, "hidden")
        B = x.shape[0]
        q_pos = jnp.full((B, 1), pos, jnp.int32)

        def repeat_body(x, xs):
            blocks_slice, cache_slice, ab_slice = xs
            new_slices = []
            for p_idx, bdef in enumerate(cfg.pattern):
                ab = None if ab_slice is None else ab_slice[p_idx]
                x, nc, _ = self._apply_block(
                    blocks_slice[p_idx], bdef, x, q_pos=q_pos, mode="decode",
                    cache=cache_slice[p_idx], write_pos=pos, act_bits=ab,
                    attn_impl=attn_impl)
                x = constrain(x, "hidden")
                new_slices.append(nc if nc is not None else cache_slice[p_idx])
            return x, tuple(new_slices)

        body, xs = self._with_act_bits(repeat_body, params, cache, act_bits)
        x, new_cache = jax.lax.scan(body, x, xs)
        return self.logits_of(params, x), new_cache

    # ------------------------------------------------------ paged decode
    def decode_step_paged(self, params, tokens, cache, block_tables, pos,
                          act_bits=None, attn_impl=None):
        """One decode step over a paged KV pool, per-sequence positions.

        tokens: (B, 1) int32; block_tables: (B, nb) int32 physical page ids
        (``paged_kv.BlockTables.as_array``); pos: (B,) int32 -- the position
        each sequence's token occupies (mixed lengths, unlike
        ``decode_step``'s single scalar).  ``cache`` is an
        ``init_paged_cache`` tuple.  Inactive batch slots carry all-trash
        block tables: their writes land in page 0 and their outputs are
        garbage the scheduler ignores.  act_bits / attn_impl as in
        :meth:`prefill`.  Returns (logits, new_cache)."""
        cfg = self.cfg
        x = self._embed_tokens(params, tokens)
        x = constrain(x, "hidden")
        B = x.shape[0]
        q_pos = pos.astype(jnp.int32)[:, None]

        def repeat_body(x, xs):
            blocks_slice, cache_slice, ab_slice = xs
            new_slices = []
            for p_idx, bdef in enumerate(cfg.pattern):
                ab = None if ab_slice is None else ab_slice[p_idx]
                x, nc, _ = self._apply_block(
                    blocks_slice[p_idx], bdef, x, q_pos=q_pos, mode="decode",
                    cache=cache_slice[p_idx], write_pos=pos,
                    block_tables=block_tables, act_bits=ab,
                    attn_impl=attn_impl)
                x = constrain(x, "hidden")
                new_slices.append(nc if nc is not None else cache_slice[p_idx])
            return x, tuple(new_slices)

        body, xs = self._with_act_bits(repeat_body, params, cache, act_bits)
        x, new_cache = jax.lax.scan(body, x, xs)
        return self.logits_of(params, x), new_cache

    # ------------------------------------------- unified token-budget step
    def model_step(self, params, tokens, positions, slot_map, cache,
                   block_tables, logit_cols, act_bits=None, attn_impl=None):
        """One token-budget step: prompt chunks and decode tokens together.

        The chunked-prefill serving loop's single entry point -- prefill and
        decode are the same call.  Row ``r`` of the fixed-shape ``(R, k)``
        batch carries slot ``slot_map[r]``'s contribution this step: a
        prompt chunk of up to ``k`` tokens, one decode token, or nothing.
        Real tokens are **left-aligned in ascending position order**; padded
        columns carry ``positions == POS_SENTINEL`` (their K/V writes route
        to the trash page and their query rows mask or are ignored).  K/V is
        written *straight into block-table pages* -- there is no dense
        intermediate cache and no per-prompt-length shape anywhere, so jit
        variants are bounded by (R, k, pool shape) alone.

        tokens / positions: (R, k) int32; slot_map: (R,) int32 row ->
        scheduler slot (selects each row's block-table row); block_tables:
        (n_slots, nb) int32; logit_cols: (R,) *or* (R, C) int32 -- the
        token columns whose hidden states feed the returned logits.  The
        1-D form is the chunked-prefill contract (each row's last real
        column, mirror of ``prefill``'s last-token slice; returns
        ``(R, 1, V)``).  The 2-D form is the speculative-verify
        generalization: ``C`` columns per row -- a speculating lane reads
        logits at *every* column of its ``[feedback, draft_1..draft_k]``
        span (repeat a column to pad; duplicates are free, it is one
        gather) and the call returns ``(R, C, V)``.  Rows without real
        tokens produce garbage the scheduler ignores.  ``cache`` is an
        ``init_paged_cache`` tuple whose kinds must all be ``"paged"``:
        recurrent ("state") and cross-attention ("memory") blocks cannot
        chunk and stay on the monolithic prefill path.  act_bits /
        attn_impl as in :meth:`prefill`.  Returns (logits (R, C, V) with
        ``C = 1`` for 1-D ``logit_cols``, new_cache).
        """
        cfg = self.cfg
        kinds = cfg.cache_kinds()
        if any(kd != "paged" for kd in kinds):
            raise ValueError(
                "model_step requires a pure paged-cache pattern (attn / "
                f"local_attn only); got cache kinds {kinds} -- serve hybrid "
                "architectures through the monolithic prefill path")
        x = self._embed_tokens(params, tokens)
        x = constrain(x, "hidden")
        q_pos = positions.astype(jnp.int32)
        bt_rows = jnp.take(block_tables, slot_map, axis=0)     # (R, nb)

        def repeat_body(x, xs):
            blocks_slice, cache_slice, ab_slice = xs
            new_slices = []
            for p_idx, bdef in enumerate(cfg.pattern):
                ab = None if ab_slice is None else ab_slice[p_idx]
                x, nc, _ = self._apply_block(
                    blocks_slice[p_idx], bdef, x, q_pos=q_pos, mode="decode",
                    cache=cache_slice[p_idx], write_pos=q_pos,
                    block_tables=bt_rows, act_bits=ab, attn_impl=attn_impl)
                x = constrain(x, "hidden")
                new_slices.append(nc)
            return x, tuple(new_slices)

        body, xs = self._with_act_bits(repeat_body, params, cache, act_bits)
        x, new_cache = jax.lax.scan(body, x, xs)
        R, _, d = x.shape
        cols = logit_cols.astype(jnp.int32)
        if cols.ndim == 1:
            cols = cols[:, None]
        C = cols.shape[1]
        idx = jnp.broadcast_to(cols[:, :, None], (R, C, d))
        return self.logits_of(params, jnp.take_along_axis(x, idx, axis=1)), \
            new_cache

    # -------------------------------------------------- activation QBNs
    def block_act_bits(self, graph: QuantizableGraph, values,
                       default: float = None) -> jnp.ndarray:
        """Collapse per-graph-site activation QBNs onto the model's hook.

        The forward takes one activation scalar per (repeat, pattern
        position) block; ``values`` is a sequence aligned with
        ``graph.layers`` (floats or traced scalars).  All sites of pattern
        position ``p`` share ``p``'s scalar and the *first* site wins --
        the block's input projection (``wq`` / ``w_xz``), whose input
        activation is the one the hook quantizes; ``wk``/``wv``/FFN share
        it.  Positions with no searched site (and the unembed, whose
        logits stay fp) get ``default`` (FULL_BITS pass-through).  This is
        the single source of the search->serve collapse: both the
        evaluator (core/evaluate.py) and the engine use it, so search-time
        evaluation and serving quantize activations identically.
        """
        from repro.quant.linear_quant import FULL_BITS
        if default is None:
            default = float(FULL_BITS)
        n_pat = len(self.cfg.pattern)
        site_pos = [int(l.name[1:].split(".")[0])
                    if l.name.startswith("p") else -1 for l in graph.layers]
        per_pos = []
        for p in range(n_pat):
            cand = [v for sp, v in zip(site_pos, values) if sp == p]
            per_pos.append(jnp.asarray(cand[0] if cand else default,
                                       jnp.float32))
        row = jnp.stack(per_pos)
        return jnp.tile(row[None, :], (self.cfg.n_repeat, 1))

    # ------------------------------------------------------- quant graph
    def graph(self, seq_len: int, batch: int,
              max_groups: int = 64) -> QuantizableGraph:
        """Quantizable-layer graph (weights of every matmul site).

        Stacked (scan) weights appear as one LayerInfo per (pattern position,
        site); its bit vector is shared across the n_repeat stack (DESIGN.md
        section 4).  Small tiny-LM configs use period == n_layers so every
        layer is searched independently, matching the paper's regime.
        """
        cfg = self.cfg
        R = cfg.n_repeat
        toks = seq_len * batch
        layers = []

        def add(name, path, c_in, c_out, macs, numel, axis, kind="linear"):
            n_groups = min(max_groups, c_out)
            layers.append(LayerInfo(
                name=name, kind=kind, c_in=c_in, c_out=c_out, k=1, stride=1,
                macs=float(macs), numel=int(numel), param_path=path,
                channel_axis=axis, n_groups=n_groups))

        d, hd = cfg.d_model, cfg.hdim
        for p_idx, bdef in enumerate(cfg.pattern):
            pre = ("blocks", p_idx)
            nm = f"p{p_idx}"
            if bdef.kind in ("attn", "local_attn", "cross_attn"):
                qd, kvd = cfg.n_heads * hd, cfg.n_kv_heads * hd
                add(f"{nm}.wq", pre + ("wq",), d, qd, R * toks * d * qd,
                    R * d * qd, -1)
                kv_toks = cfg.n_img_tokens * batch \
                    if bdef.kind == "cross_attn" else toks
                add(f"{nm}.wk", pre + ("wk",), d, kvd, R * kv_toks * d * kvd,
                    R * d * kvd, -1)
                add(f"{nm}.wv", pre + ("wv",), d, kvd, R * kv_toks * d * kvd,
                    R * d * kvd, -1)
                add(f"{nm}.wo", pre + ("wo",), qd, d, R * toks * qd * d,
                    R * qd * d, -1)
            else:
                s = cfg.ssm
                di = s.d_inner(d)
                add(f"{nm}.w_xz", pre + ("mamba", "w_xz"), d, 2 * di,
                    R * toks * d * 2 * di, R * d * 2 * di, -1)
                add(f"{nm}.w_bc", pre + ("mamba", "w_bc"), d, 2 * s.d_state,
                    R * toks * d * 2 * s.d_state, R * d * 2 * s.d_state, -1)
                add(f"{nm}.w_out", pre + ("mamba", "w_out"), di, d,
                    R * toks * di * d, R * di * d, -1)
            if bdef.has_ffn:
                if bdef.use_moe:
                    m = cfg.moe
                    eff_toks = toks * m.top_k / m.n_experts
                    for site, cin, cout in (("wg", d, m.d_ff),
                                            ("wu", d, m.d_ff),
                                            ("wd", m.d_ff, d)):
                        add(f"{nm}.{site}", pre + (site,), cin, cout,
                            R * m.n_experts * eff_toks * cin * cout,
                            R * m.n_experts * cin * cout, -1, kind="expert")
                else:
                    add(f"{nm}.wg", pre + ("wg",), d, cfg.d_ff,
                        R * toks * d * cfg.d_ff, R * d * cfg.d_ff, -1)
                    add(f"{nm}.wu", pre + ("wu",), d, cfg.d_ff,
                        R * toks * d * cfg.d_ff, R * d * cfg.d_ff, -1)
                    add(f"{nm}.wd", pre + ("wd",), cfg.d_ff, d,
                        R * toks * cfg.d_ff * d, R * cfg.d_ff * d, -1)
        add("unembed", ("unembed",), d, cfg.vocab_padded,
            toks * d * cfg.vocab_padded, d * cfg.vocab_padded, -1,
            kind="unembed")
        return QuantizableGraph(layers=layers)
