"""Model configuration dataclasses and the public LM protocol.

An :class:`LMConfig` fully describes a decoder LM as a *periodic pattern* of
blocks repeated ``n_repeat`` times -- e.g. Jamba's (7 mamba + 1 attn) period,
gemma2's (local, global) pairs, llama-3.2-vision's (4 self + 1 cross).  The
periodic layout is what lets every stack lower as ``lax.scan`` over repeats,
keeping HLO size O(period) instead of O(depth) (DESIGN.md section 5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden width
    capacity_factor: float = 1.25
    pad_to: Optional[int] = None  # physical expert count (EP divisibility);
                                  # padded experts are never routed to
    local_dispatch: bool = False  # shard_map dispatch over DP (small experts)

    @property
    def n_experts_phys(self) -> int:
        return self.pad_to or self.n_experts


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256             # SSD block-decomposition chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class BlockDef:
    """One block of the periodic pattern."""
    kind: str                    # "attn" | "local_attn" | "mamba" | "cross_attn"
    use_moe: bool = False        # MoE FFN instead of dense FFN
    has_ffn: bool = True         # mamba2-style blocks have no separate FFN


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    n_layers: int
    pattern: Tuple[BlockDef, ...]
    head_dim: Optional[int] = None
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    rope_theta: float = 1e4
    window: Optional[int] = None          # sliding window for local_attn blocks
    attn_softcap: Optional[float] = None  # gemma2: 50.0
    logit_softcap: Optional[float] = None # gemma2: 30.0
    n_img_tokens: int = 0                 # vlm: cross-attn memory length
    frontend: Optional[str] = None        # None | "audio_stub" | "vision_stub"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not a multiple of "
            f"pattern length {len(self.pattern)}")

    @property
    def n_repeat(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def hdim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 128 so the embedding/unembedding
        tables shard over the model axis (Megatron-style padding; padded
        logits are masked to -inf in logits_of)."""
        return -(-self.vocab // 128) * 128

    @property
    def q_groups(self) -> int:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0
        return self.n_heads // max(self.n_kv_heads, 1)

    def has_kind(self, kind: str) -> bool:
        return any(b.kind == kind for b in self.pattern)

    def cache_kinds(self) -> Tuple[str, ...]:
        """Decode-state kind per pattern position, from the serving engine's
        point of view:

        * ``"paged"``  -- self-attention (global or sliding-window): per-token
          K/V that a paged pool can hold (serve/paged_kv.py);
        * ``"memory"`` -- cross-attention: a fixed-length per-sequence memory
          written once at prefill, read-only during decode;
        * ``"state"``  -- recurrent (mamba) state: O(1)-size per sequence,
          indexed by batch slot, no paging needed.

        The paged serving path (transformer.init_paged_cache, serve/engine
        ``run``) keys its cache layout and prefill scatter off this tuple.
        """
        out = []
        for b in self.pattern:
            if b.kind == "mamba":
                out.append("state")
            elif b.kind == "cross_attn":
                out.append("memory")
            else:
                out.append("paged")
        return tuple(out)

    @property
    def is_subquadratic(self) -> bool:
        """True when decode state does not require a full-attention KV cache
        in every block (SSM / hybrid / local+global alternation)."""
        full_attn = sum(b.kind in ("attn", "cross_attn") for b in self.pattern)
        return full_attn < len(self.pattern)


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    """One assigned (input-shape) cell."""
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    mode: str                    # "train" | "prefill" | "decode"


SHAPES = (
    ShapeCfg("train_4k", 4096, 256, "train"),
    ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    ShapeCfg("decode_32k", 32768, 128, "decode"),
    ShapeCfg("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeCfg:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
