"""Core LM layers: RMSNorm, RoPE, GQA attention, SwiGLU FFN,
capacity-based top-k MoE.  Pure functions over explicit parameter dicts.

Attention dispatches between two backends through an ``impl`` selector:

* ``impl="ref"`` (default) -- a running-logsumexp scan over KV chunks
  (flash-attention schedule in jnp) so prefill at 32k..512k sequence
  lengths never materializes an (Sq, Skv) score matrix.  This is the
  bit-accuracy oracle; the train path always uses it.
* ``impl="pallas"`` -- the fused kernels in ``kernels/attention.py``: a
  tiled flash forward for prefill/dense decode, and a block-table-aware
  paged decode kernel that streams KV pages into VMEM instead of running
  the dense ``paged_gather``.  The serving engine defaults to this path.

``attention`` / ``paged_attention`` are the dispatchers; ``attention_ref``
/ ``paged_attention_ref`` are the jnp implementations (kept public: tests
pin them as the oracle).  ``impl`` must be static under jit.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.pack import PackedWeight
from repro.quant.linear_quant import fake_quant_per_token

NEG_INF = float("-inf")


# --------------------------------------------------------------------- basics
def wcol(w):
    """Column-parallel weight at use: gather FSDP shards, keep TP shard.

    Under the "weight_gather" rules (launch/steps.py) this pins the gathered
    layout P(None, "model") so GSPMD gathers the (cheap) weight over "data"
    instead of resharding the (expensive) activations every matmul."""
    from repro.sharding.ctx import constrain
    return constrain(deq(w), "w_col")


def wrow(w):
    """Row-parallel weight at use: gathered layout P("model", None)."""
    from repro.sharding.ctx import constrain
    return constrain(deq(w), "w_row")


def deq(w):
    """Dequantize quantized-serving weights at use.

    Two stored layouts dispatch here:
      * {"q": int8, "s": scale} -- uniform int8 (quantize_params_int8);
      * kernels.pack.PackedWeight -- the bucketed sub-byte layout a searched
        mixed-QBN policy compiles to (apply_policy_packed): QBN <= 4 channels
        bit-packed along K, 5..8 int8, > 8 bf16.
    On TPU the unpack/convert+scale fuses into the consuming matmul, so HBM
    weight traffic matches the stored width (1 byte, 1/2 byte, 1/4 byte per
    element; kernels/quant_matmul.py and kernels/packed_matmul.py are the
    explicit-tiling versions of the same contractions).  Full-precision
    leaves pass through untouched.
    """
    if isinstance(w, PackedWeight):
        return w.dequant()
    if isinstance(w, dict) and "q" in w:
        return w["q"].astype(w["s"].dtype) * w["s"]
    return w


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding.  x: (B, S, H, D); pos: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[..., None] * freqs          # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def maybe_quant_act(x: jnp.ndarray, bits) -> jnp.ndarray:
    """Per-token activation fake-quant; bits None/static-0 disables.

    Row-wise dynamic scales (amax over the model dim) keep each token's
    quantization independent of its batch: a continuous-batching decode
    step quantizes a sequence's activation exactly as the batch-1 oracle
    would -- the invariant behind run()/generate() parity under a policy
    with activation QBNs (tests/test_paged_kv.py).
    """
    if bits is None:
        return x
    return fake_quant_per_token(x, bits)


# ------------------------------------------------------------------ attention
def _mask_scores(s, q_pos, kv_pos, *, causal, window, kv_valid_len):
    """s: (B, Hkv, G, Sq, Ck); q_pos (B,Sq); kv_pos (B,Ck)."""
    qp = q_pos[:, None, None, :, None]
    kp = kv_pos[:, None, None, None, :]
    mask = jnp.ones(s.shape, bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    if kv_valid_len is not None:
        kv = kv_valid_len.reshape(-1, 1, 1, 1, 1)
        mask &= kp < kv
    return jnp.where(mask, s, NEG_INF)


ATTN_IMPLS = ("ref", "pallas")


def _check_impl(impl):
    impl = impl or "ref"
    if impl not in ATTN_IMPLS:
        raise ValueError(f"unknown attention impl {impl!r}; "
                         f"expected one of {ATTN_IMPLS}")
    return impl


def attention(q, k, v, *, q_pos, kv_pos, causal=True, window=None,
              attn_cap=None, kv_valid_len=None, chunk=1024, impl=None):
    """GQA attention dispatcher: ``impl="ref"`` (jnp oracle, default) or
    ``"pallas"`` (kernels/attention.flash_attention).

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); q_pos: (B, Sq) int32;
    kv_pos: (B, Skv) int32.  Returns (B, Sq, Hq, D) in q.dtype.  ``impl``
    must be static under jit; ``kv_valid_len`` (ragged prefill batches)
    stays on the ref path -- the kernels express validity through positions
    alone.  ``chunk`` applies to the ref path only.
    """
    impl = _check_impl(impl)
    if impl == "pallas" and kv_valid_len is None:
        from repro.kernels.attention import flash_attention
        return flash_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                               causal=causal, window=window,
                               attn_cap=attn_cap)
    return attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal,
                         window=window, attn_cap=attn_cap,
                         kv_valid_len=kv_valid_len, chunk=chunk)


def attention_ref(q, k, v, *, q_pos, kv_pos, causal=True, window=None,
                  attn_cap=None, kv_valid_len=None, chunk=1024):
    """GQA attention with a flash (running-softmax) scan over KV chunks.

    The pure-jnp oracle the Pallas kernels are property-tested against.
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, G, D)

    def score(kc, kvp):  # kc: (B, Ck, Hkv, D) -> (B, Hkv, G, Sq, Ck)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kc.astype(jnp.float32))
        s = softcap(s, attn_cap)
        return _mask_scores(s, q_pos, kvp, causal=causal, window=window,
                            kv_valid_len=kv_valid_len)

    if Skv <= chunk:
        s = score(k, kv_pos)
        m = jnp.max(s, axis=-1, keepdims=True)
        msafe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(s - msafe)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
        o = o / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2, 4)
        return o.reshape(B, Sq, Hq, D).astype(q.dtype)

    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)),
                         constant_values=jnp.iinfo(jnp.int32).max)
    kcs = k.reshape(B, n_chunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vcs = v.reshape(B, n_chunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    pcs = kv_pos.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    o0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)

    def body(carry, xs):
        m, l, o = carry
        kc, vc, kvp = xs
        s = score(kc, kvp)                                   # (B,Hkv,G,Sq,Ck)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bqhgd", p, vc.astype(jnp.float32))
        o = o * alpha.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l, o), None

    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), (kcs, vcs, pcs))
    o = o / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


# ----------------------------------------------------- paged-KV attention
def paged_gather(pages: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    """Gather per-sequence KV through block tables.

    pages: (P, page_size, ...) physical pool; block_tables: (B, nb) int32
    physical page ids (logical block order).  Returns (B, nb*page_size, ...)
    -- each sequence's pages flattened back into logical position order.
    Unmapped blocks point at the trash page (id 0); its slots carry sentinel
    positions, so the attention mask rejects them.
    """
    g = pages[block_tables]                      # (B, nb, ps, ...)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def paged_attention(q, k_pages, v_pages, pos_pages, block_tables, *, q_pos,
                    causal=True, window=None, attn_cap=None,
                    k_scale_pages=None, v_scale_pages=None, impl=None):
    """Attention over a paged KV pool: dispatcher (decode *and* chunks).

    q: (B, Sq, Hq, D) -- ``Sq == 1`` is the decode step, ``Sq > 1`` a
    prompt chunk whose K/V were already scattered into the pool this step;
    ``*_pages``: (P, page_size, Hkv, D) (``pos_pages`` (P, page_size)
    int32); block_tables: (B, nb); q_pos: (B, Sq) int32, real rows
    left-aligned and sentinel-padded.  int8 pools carry per-(slot, head)
    ``*_scale_pages`` (P, page_size, Hkv) f32.

    ``impl="ref"`` (default) gathers each sequence's pages into logical
    order and runs the standard masked flash attention; ``"pallas"``
    (kernels/attention.paged_prefill_attention) walks the block table
    in-kernel, streaming pages into VMEM with no dense gather.  Slots whose
    position is the sentinel (unwritten, scrubbed, or trash) mask to -inf
    exactly like the dense cache's convention on both paths, so the result
    matches dense-cache decode on the same written positions.
    """
    impl = _check_impl(impl)
    if impl == "pallas" and causal:
        from repro.kernels.attention import paged_prefill_attention
        return paged_prefill_attention(
            q, k_pages, v_pages, pos_pages, block_tables, q_pos=q_pos,
            window=window, attn_cap=attn_cap, k_scale_pages=k_scale_pages,
            v_scale_pages=v_scale_pages)
    return paged_attention_ref(
        q, k_pages, v_pages, pos_pages, block_tables, q_pos=q_pos,
        causal=causal, window=window, attn_cap=attn_cap,
        k_scale_pages=k_scale_pages, v_scale_pages=v_scale_pages)


def paged_attention_ref(q, k_pages, v_pages, pos_pages, block_tables, *,
                        q_pos, causal=True, window=None, attn_cap=None,
                        k_scale_pages=None, v_scale_pages=None):
    """jnp oracle for paged decode: dense gather + masked flash attention.

    The gather materializes each sequence's whole (nb*page_size) KV window
    -- the HBM round trip the Pallas kernel exists to avoid; int8 pools
    additionally dequantize the entire gathered window to f32 here.
    """
    k = paged_gather(k_pages, block_tables)
    v = paged_gather(v_pages, block_tables)
    kv_pos = paged_gather(pos_pages, block_tables)
    if k_scale_pages is not None:
        ks = paged_gather(k_scale_pages, block_tables)
        vs = paged_gather(v_scale_pages, block_tables)
        k = k.astype(jnp.float32) * ks[..., None]
        v = v.astype(jnp.float32) * vs[..., None]
    return attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal,
                         window=window, attn_cap=attn_cap, chunk=k.shape[1])


# ----------------------------------------------------------------------- FFN
def swiglu(x, p, act_bits=None):
    """p: {wg: (d, ff), wu: (d, ff), wd: (ff, d)}."""
    x = maybe_quant_act(x, act_bits)
    h = jax.nn.silu(x @ wcol(p["wg"])) * (x @ wcol(p["wu"]))
    return h @ wrow(p["wd"])


# ----------------------------------------------------------------------- MoE
def moe_ffn(x, p, *, n_experts, top_k, capacity_factor=1.25, act_bits=None,
            local_dispatch=False):
    """Capacity-based top-k MoE with scatter dispatch (no TxExC one-hot).

    x: (..., d).  p: {router: (d, E), wg/wu: (E, d, ff), wd: (E, ff, d)}.
    Tokens beyond an expert's capacity are dropped (standard Switch-style),
    contributing only their residual path.  capacity_factor <= 0 disables
    dropping (C = T; exact but unbalanced -- used by tiny smoke configs).

    local_dispatch=True (small-expert MoE under a mesh): split tokens into
    one group per data shard and vmap the dispatch over groups, with the
    group dim pinned to the DP axes -- every routing cumsum/scatter becomes
    shard-local, eliminating the cross-data all-reduce of the (E, C, d)
    dispatch buffer.  Pairs with DP-replicated (TP-sharded) expert weights
    (sharding/specs.py honors cfg.moe.local_dispatch), which is the right
    trade for small experts (EXPERIMENTS.md §Perf, granite hillclimb).
    """
    from repro.sharding.ctx import constrain, current_mesh
    mesh = current_mesh() if local_dispatch else None
    if mesh is not None:
        G = 1
        for a in ("pod", "data"):
            G *= mesh.shape.get(a, 1)
        T = 1
        for dim in x.shape[:-1]:
            T *= dim
        if G > 1 and T % G == 0:
            d = x.shape[-1]
            xg = constrain(x.reshape(G, T // G, d), "moe_group")

            def one_group(xl):
                return _moe_ffn_impl(
                    xl, p, n_experts=n_experts, top_k=top_k,
                    capacity_factor=capacity_factor, act_bits=act_bits)

            out, probs = jax.vmap(one_group)(xg)
            out = constrain(out, "moe_group")
            return out.reshape(x.shape), probs.reshape(T, -1)
    return _moe_ffn_impl(x, p, n_experts=n_experts, top_k=top_k,
                         capacity_factor=capacity_factor, act_bits=act_bits)


def _moe_ffn_impl(x, p, *, n_experts, top_k, capacity_factor, act_bits):
    orig_shape = x.shape
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    T = xt.shape[0]
    E, K = n_experts, top_k
    wg, wu, wd = deq(p["wg"]), deq(p["wu"]), deq(p["wd"])
    E_phys = wg.shape[0]          # >= E when experts are padded for EP
    if capacity_factor <= 0:
        C = T
    else:
        C = min(T, max(8, int(math.ceil(T * K / E * capacity_factor))))

    # router matmul in model dtype (f32 softmax after): an f32 upcast of xt
    # here promotes the whole dispatch backward to f32, doubling the TP
    # all-reduce of the (E, C, d) buffer cotangent (§Perf, jamba hillclimb)
    logits = (xt @ deq(p["router"]).astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gate_v, gate_i = jax.lax.top_k(probs, K)                  # (T, K)
    gate_v = gate_v / jnp.maximum(gate_v.sum(-1, keepdims=True), 1e-9)

    # Flatten (token, slot) pairs and compute position-in-expert by cumsum.
    eidx = gate_i.reshape(-1)                                 # (T*K,)
    onehot = jax.nn.one_hot(eidx, E_phys, dtype=jnp.int32)    # (T*K, E_phys)
    pos_all = jnp.cumsum(onehot, axis=0) - 1                  # (T*K, E_phys)
    pos = jnp.take_along_axis(pos_all, eidx[:, None], axis=1)[:, 0]
    keep = pos < C

    xq = maybe_quant_act(xt, act_bits)
    xrep = jnp.repeat(xq, K, axis=0)                          # (T*K, d)
    buf = jnp.zeros((E_phys, C, d), xt.dtype)
    buf = buf.at[eidx, jnp.clip(pos, 0, C - 1)].add(
        jnp.where(keep[:, None], xrep, 0))

    h = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(h) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd)               # (E_phys, C, d)

    gathered = out_buf[eidx, jnp.clip(pos, 0, C - 1)]         # (T*K, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    weighted = gathered * gate_v.reshape(-1)[:, None].astype(gathered.dtype)
    out = weighted.reshape(T, K, d).sum(axis=1)
    return out.reshape(orig_shape), probs


def moe_aux_loss(probs, gate_i, n_experts):
    """Switch-style load-balance loss from router probs + top-1 assignment."""
    T = probs.shape[0]
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_i[:, 0], n_experts, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(frac_tokens * frac_probs)
