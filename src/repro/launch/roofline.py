"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, derive the three roofline terms from the
loop-corrected HLO stats recorded by dryrun.py:

  compute    = HLO_FLOPs_per_device / peak          (197 TFLOP/s bf16)
  memory     = HLO_bytes_traffic_per_device / HBM_bw  (819 GB/s; fusion-
               granularity reads+writes, dynamic-update-slice in-place)
  collective = per_chip_link_bytes / link_bw        (~50 GB/s/link ICI)

plus MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (prefill/decode) and
the usefulness ratio MODEL_FLOPS / HLO_FLOPs_global.

Usage: python -m repro.launch.roofline [--dir results/dryrun] [--mesh single]
Writes results/roofline.json and prints the markdown table.
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict

import numpy as np

PEAK_BF16 = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_ADVICE = {
    ("compute", "train"): "fewer recompute FLOPs: loosen remat policy or "
    "checkpoint only FFN inputs; the rest is useful math",
    ("compute", "prefill"): "attention chunk sizes tuned for MXU occupancy; "
    "flops here are mostly useful",
    ("compute", "decode"): "batch more decode requests per step to amortize "
    "weight reads into MXU work",
    ("memory", "train"): "reduce materialized temporaries: fuse optimizer "
    "update, chunk the vocab loss, drop f32 logit buffers",
    ("memory", "prefill"): "stream KV-cache writes and keep attention "
    "workspaces in VMEM-sized chunks",
    ("memory", "decode"): "quantize weights/KV (AutoQ int8/int4 policies) -- "
    "decode is weight/KV-bandwidth bound, exactly the term AutoQ shrinks",
    ("collective", "train"): "re-balance FSDP vs TP: gather weights once per "
    "layer (not per matmul), overlap all-gathers with compute, compress "
    "pod-level gradient all-reduce to int8",
    ("collective", "prefill"): "shard sequence instead of gathering KV; "
    "combine partial softmax across shards",
    ("collective", "decode"): "keep decode activations model-sharded end-to-"
    "end; avoid per-step re-gathering of small tensors",
}


def count_params(cfg) -> Dict[str, float]:
    import jax
    from repro.launch.specs import params_struct
    from repro.models.transformer import LM
    sds = params_struct(LM(cfg))
    total = expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(sds)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        if len(leaf.shape) == 4 and any(k in ("wg", "wu", "wd")
                                        for k in keys):
            expert += n
    active = total - expert
    if cfg.moe is not None and expert:
        active += expert * cfg.moe.top_k / cfg.moe.n_experts
    return {"total": float(total), "active": float(active)}


def model_flops(cfg, shape, n_params: Dict[str, float]) -> float:
    toks = shape.global_batch * (1 if shape.mode == "decode" else
                                 shape.seq_len)
    mult = 6.0 if shape.mode == "train" else 2.0
    return mult * n_params["active"] * toks


def analyze_cell(r: dict, cfg, shape) -> dict:
    hs = r.get("hlo_stats", {})
    flops_dev = hs.get("flops_per_device", 0.0)
    traffic_dev = hs.get("bytes_traffic_per_device",
                         2.0 * hs.get("bytes_written_per_device", 0.0))
    coll = r.get("collectives", {}).get("per_chip_bytes", 0.0)
    n_dev = r.get("devices", 256)
    t_compute = flops_dev / PEAK_BF16
    t_memory = traffic_dev / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)
    npar = count_params(cfg)
    mf = model_flops(cfg, shape, npar)
    hlo_global = flops_dev * n_dev
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "mode": shape.mode, "devices": n_dev,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dom,
        "bound_frac": terms[dom] / max(sum(terms.values()), 1e-30),
        "roofline_frac": t_compute / max(max(terms.values()), 1e-30),
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "params_total": npar["total"], "params_active": npar["active"],
        "advice": _ADVICE[(dom, shape.mode)],
    }


def main():
    from repro.configs import ARCHS
    from repro.models.api import shape_by_name

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args()

    rows = []
    for f in sorted(pathlib.Path(args.dir).glob(f"*__{args.mesh}.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        cfg = ARCHS[r["arch"]].config
        shape = shape_by_name(r["shape"])
        rows.append(analyze_cell(r, cfg, shape))

    pathlib.Path(args.out).write_text(json.dumps(rows, indent=1))
    hdr = (f"| {'arch':26s} | {'shape':11s} | compute | memory | collect | "
           f"dom | useful |")
    print(hdr)
    print("|" + "-" * (len(hdr) - 2) + "|")
    for c in rows:
        print(f"| {c['arch']:26s} | {c['shape']:11s} "
              f"| {c['t_compute_s']:.2e} | {c['t_memory_s']:.2e} "
              f"| {c['t_collective_s']:.2e} | {c['dominant'][:4]} "
              f"| {c['useful_ratio']:.3f} |")


if __name__ == "__main__":
    main()
