"""Serving launcher: batched generation with an optional AutoQ policy.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
        --bits 8 --n-new 32
"""
import argparse

import jax
import numpy as np

from repro.configs import get
from repro.data import TokenStream
from repro.models import LM
from repro.quant.policy import QuantPolicy
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--bits", type=float, default=0,
                    help="uniform weight QBN (0 = full precision)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--attn-impl", choices=("pallas", "ref"),
                    default="pallas",
                    help="attention backend (ref = jnp oracle path)")
    ap.add_argument("--kv-bits", type=int, default=0,
                    help="8 = int8 KV cache (dense and paged)")
    args = ap.parse_args()

    spec = get(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    if cfg.frontend == "audio_stub":
        raise SystemExit("audio_stub archs need frame embeddings; use the "
                         "dry-run for musicgen serving shapes")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    policy = graph = None
    if args.bits > 0:
        graph = model.graph(seq_len=args.prompt_len, batch=args.batch)
        policy = QuantPolicy.uniform(graph, args.bits)

    eng = ServeEngine(model, params, policy=policy, graph=graph,
                      max_len=args.prompt_len + args.n_new,
                      attn_impl=args.attn_impl,
                      kv_bits=args.kv_bits or None)
    prompts = TokenStream(vocab=cfg.vocab).batch(
        0, args.batch, args.prompt_len)["tokens"]
    out = eng.generate(prompts, n_new=args.n_new,
                       temperature=args.temperature)
    s = out["stats"]
    print(f"prefill {s.prefill_s*1e3:.1f} ms | decode "
          f"{s.decode_tok_per_s:.1f} tok/s | {s.tokens_out} tokens")
    print("sample:", out["tokens"][0][:24].tolist())


if __name__ == "__main__":
    main()
