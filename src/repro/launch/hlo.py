"""Post-SPMD HLO analysis: FLOPs, byte and collective-traffic extraction.

``compiled.cost_analysis()`` counts every while body ONCE, but scan-lowered
stacks execute their bodies trip-count times -- for a 48-repeat layer scan it
under-reports FLOPs by ~48x.  This module re-derives the three roofline
numerators from ``compiled.as_text()`` (post-partitioning, per-device
shapes), multiplying every instruction by the product of enclosing while
trip counts (taken from backend_config known_trip_count, falling back to the
loop-bound constant in the condition computation):

* flops: 2 * result_elems * contracted_size for every dot (+ convolution).
* bytes_traffic: fusion-granularity HBM traffic -- for every compute
  instruction (fusion, dot, slice, ...), operand bytes (reads) + result
  bytes (writes).  dynamic-update-slice -- top-level or as a fusion root --
  counts 2x the update slice instead of the whole buffer (in-place on TPU),
  which is what makes decode-step KV-cache accounting sane.  XLA:TPU fuses
  more aggressively than the CPU text this parses, so it is an upper bound.
* collectives: ring-model link bytes per chip (all-gather/all-to-all move
  (n-1)/n of the result, reduce-scatter (n-1)x the scattered result,
  all-reduce 2(n-1)/n, permute 1x), group size n from replica_groups.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")

_SKIP_OPS = ("parameter(", "constant(", "tuple(", "get-tuple-element(",
             "bitcast(", "bitcast-convert(", "copy(", "after-all(",
             "partition-id(", "replica-id(", "iota(", "reshape(",
             "broadcast(", "while(", "conditional(", "call(",
             "custom-call(", "rng", "opt-barrier(")


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_type(rhs: str) -> str:
    """Type annotation before the opcode in '<type> opcode(...)'."""
    m = re.match(r"((?:\([^)]*\))|(?:\S+))\s", rhs)
    return m.group(1) if m else ""


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return default


def _ring_factor(kind: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind == "reduce-scatter":
        return float(n - 1)
    if kind == "collective-permute":
        return 1.0
    return (n - 1) / n          # all-gather, all-to-all


@dataclasses.dataclass
class HLOStats:
    flops: float = 0.0
    bytes_traffic: float = 0.0      # reads + writes, fusion granularity
    coll_per_chip_bytes: float = 0.0
    coll_op_counts: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    coll_op_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    parse_warnings: List[str] = dataclasses.field(default_factory=list)

    @property
    def bytes_written(self) -> float:   # backwards-compat alias
        return self.bytes_traffic / 2.0


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for raw in hlo.splitlines():
        s = raw.strip()
        if not s or s.startswith("//") or s.startswith("HloModule"):
            continue
        if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
            toks = s.split()
            name = toks[1] if s.startswith("ENTRY") else toks[0]
            cur = name.lstrip("%").split("(")[0].rstrip(",")
            comps[cur] = []
        elif s == "}" or s.startswith("} "):
            cur = None
        elif cur is not None:
            comps[cur].append(s)
    return comps


def _instr_types(comps: Dict[str, List[str]]):
    """instruction name -> (result type string, opcode, operand names)."""
    types: Dict[str, str] = {}
    defs: Dict[str, Tuple[str, List[str]]] = {}
    for lines in comps.values():
        for ln in lines:
            m = _INSTR_RE.match(ln)
            if not m:
                continue
            rtype = _result_type(m.group(2))
            body = m.group(2)[len(rtype):].lstrip()
            op = body.split("(")[0]
            types[m.group(1)] = rtype
            defs[m.group(1)] = (op, _operand_names(body))
    return types, defs


# Elementwise/layout ops through which a weight-dequant chain passes; on TPU
# these fuse into the consumer, so an operand read is charged at the
# *smallest* tensor along the chain (an int8 weight read stays 1 B/elem even
# though the CPU text materializes the converted f32).
_CHAIN_OPS = ("convert", "multiply", "transpose", "reshape", "bitcast",
              "copy", "negate", "divide", "add", "subtract")


def _effective_bytes(name: str, types, defs, depth: int = 8) -> int:
    best = _tensor_bytes(types.get(name, ""))
    cur = name
    for _ in range(depth):
        op, operands = defs.get(cur, ("", []))
        if op not in _CHAIN_OPS or not operands:
            break
        big = max(operands, key=lambda o: _tensor_bytes(types.get(o, "")),
                  default=None)
        if big is None:
            break
        best = min(best, max(_tensor_bytes(types.get(big, "")), 1))
        cur = big
    return best


def _while_multipliers(comps, warnings) -> Dict[str, float]:
    body_of: List[Tuple[str, str, str, float]] = []
    for parent, lines in comps.items():
        for ln in lines:
            if " while(" in ln:
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                mt = _TRIP_RE.search(ln)
                trip = float(mt.group(1)) if mt else None
                if mb and mc:
                    body_of.append((parent, mb.group(1), mc.group(1), trip))

    def cond_trip(cond_name: str) -> float:
        best = 0
        for ln in comps.get(cond_name, []):
            for m in re.finditer(r"constant\((\d+)\)", ln):
                best = max(best, int(m.group(1)))
        if best == 0:
            warnings.append(f"no trip count for {cond_name}; assuming 1")
            return 1.0
        return float(best)

    mult: Dict[str, float] = defaultdict(lambda: 1.0)
    for _ in range(6):              # fixpoint over nesting depth
        changed = False
        for parent, body, cond, trip in body_of:
            t = trip if trip is not None else cond_trip(cond)
            new = mult[parent] * t
            if mult[body] != new:
                mult[body] = new
                changed = True
        if not changed:
            break
    return mult


def _dot_flops(ln: str, result_type: str,
               types: Dict[str, str]) -> Optional[float]:
    shapes = _shape_dims(result_type)
    if not shapes:
        return None
    _, rdims = shapes[0]
    out_elems = 1
    for d in rdims:
        out_elems *= d
    # operands may carry inline types ('dot(f32[8,16]{1,0} %lhs, ...)' --
    # older jax HLO text) or be bare names ('dot(%lhs, ...)')
    mo = re.search(
        r"dot\((?:([a-z0-9]+\[[0-9,]*\])(?:\{[^}]*\})?\s+)?%?([\w\.\-]+)", ln)
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln)
    if not (mo and mc):
        return 2.0 * out_elems      # degenerate: no contraction info
    lhs_type = mo.group(1) or types.get(mo.group(2), "")
    lshapes = _shape_dims(lhs_type)
    if not lshapes:
        return 2.0 * out_elems
    _, ldims = lshapes[0]
    k = 1
    for idx in mc.group(1).split(","):
        if idx and int(idx) < len(ldims):
            k *= ldims[int(idx)]
    return 2.0 * out_elems * k


_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")


def _operand_names(body: str) -> List[str]:
    """Names inside the top-level parens of 'op(...)' (before attributes)."""
    start = body.find("(")
    if start < 0:
        return []
    depth, end = 0, start
    for i, ch in enumerate(body[start:], start):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERANDS_RE.findall(body[start:end + 1])


def _fusion_roots(comps) -> Dict[str, str]:
    """fused computation name -> its ROOT line."""
    roots = {}
    for cname, lines in comps.items():
        for ln in lines:
            if ln.startswith("ROOT "):
                roots[cname] = ln
    return roots


def _fusion_traffic(comp_lines: List[str], types) -> float:
    """HBM traffic of one fusion call, analyzed per parameter.

    A parameter consumed only through dynamic-slice reads its slices, not
    the whole buffer; a parameter that is the in-place target of a
    dynamic-update-slice is aliased (0 read); everything else reads fully.
    Writes: the update sizes of internal dynamic-update-slices if any
    (the output buffer aliases the input), else the root result.
    """
    instrs = []        # (name, op, rtype, operands)
    params: Dict[str, int] = {}
    root = None
    for ln in comp_lines:
        mi = _INSTR_RE.match(ln)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        rtype = _result_type(rhs)
        body = rhs[len(rtype):].lstrip()
        op = body.split("(")[0]
        operands = _operand_names(body)
        instrs.append((name, op, rtype, operands))
        if op == "parameter":
            params[name] = _tensor_bytes(rtype)
        if ln.startswith("ROOT "):
            root = (name, op, rtype, operands)

    consumers: Dict[str, List[Tuple[str, str, str, List[str]]]] = {}
    for ins in instrs:
        for o in ins[3]:
            consumers.setdefault(o, []).append(ins)

    # read size of a value: slices read slice-sized; pure layout/dtype hops
    # (convert/bitcast/reshape/transpose/copy) defer to *their* consumers
    # (on TPU these fuse away and the buffer is never re-materialized).
    def resolve(name: str, size: int, depth: int = 6) -> float:
        if depth == 0:
            return size
        uses = consumers.get(name, [])
        if not uses:
            return 0.0
        total = 0.0
        for uname, uop, urtype, uoperands in uses:
            if uop == "dynamic-slice" and uoperands[0] == name:
                total += _tensor_bytes(urtype)
            elif uop == "dynamic-update-slice" and uoperands[0] == name:
                total += 0.0              # in-place alias target
            elif uop in ("convert", "bitcast", "reshape", "transpose",
                         "copy"):
                total += resolve(uname, min(size, _tensor_bytes(urtype)),
                                 depth - 1)
            else:
                total += size
                break
        return min(total, size * len(uses))

    reads = sum(resolve(p, b) for p, b in params.items())

    dus_updates = 0.0
    for name, op, rtype, operands in instrs:
        if op == "dynamic-update-slice" and len(operands) >= 2:
            dus_updates += _tensor_bytes(types.get(operands[1], ""))
    writes = dus_updates if dus_updates else (
        _tensor_bytes(root[2]) if root else 0.0)
    return reads + writes


def analyze(hlo_text: str, default_group: int) -> HLOStats:
    stats = HLOStats()
    comps = _split_computations(hlo_text)
    if not comps:
        stats.parse_warnings.append("no computations parsed")
        return stats
    types, defs = _instr_types(comps)
    mult = _while_multipliers(comps, stats.parse_warnings)
    roots = _fusion_roots(comps)
    counts = defaultdict(float)
    cbytes = defaultdict(float)

    # computations reached via calls= (fusions/calls): their instructions
    # contribute FLOPs only -- their memory traffic is accounted at the call
    # site -- and inherit the caller's loop multiplier.
    called: Dict[str, float] = {}
    for _ in range(4):              # propagate through nested calls
        changed = False
        for cname, lines in comps.items():
            m = called.get(cname, mult.get(cname, 1.0))
            for ln in lines:
                for mc in re.finditer(r"calls=%?([\w\.\-]+)", ln):
                    tgt = mc.group(1)
                    if called.get(tgt) != m:
                        called[tgt] = m
                        changed = True
        if not changed:
            break

    def dus_traffic(dus_line: str) -> float:
        """2x the update-slice bytes (in-place read-modify-write)."""
        ops = _operand_names(dus_line.split("=", 1)[-1])
        if len(ops) >= 2 and ops[1] in types:
            return 2.0 * _tensor_bytes(types[ops[1]])
        return 0.0

    for cname, lines in comps.items():
        in_called = cname in called
        m = called.get(cname, mult.get(cname, 1.0))
        for ln in lines:
            mi = _INSTR_RE.match(ln)
            if not mi:
                continue
            rhs = mi.group(2)
            rtype = _result_type(rhs)
            body = rhs[len(rtype):].lstrip()

            if in_called:           # fusion/call body: FLOPs only
                if body.startswith("dot("):
                    f = _dot_flops(ln, rtype, types)
                    if f:
                        stats.flops += f * m
                continue

            # --- collectives ---
            # XLA:CPU upcasts bf16 dots to f32, so collectives ride f32
            # tensors the TPU would move as bf16; chase each operand to its
            # source dtype and move min(result, sources) bytes.
            matched_coll = False
            for kind in COLLECTIVES:
                if body.startswith(f"{kind}(") or \
                        body.startswith(f"{kind}-start("):
                    nbytes = _tensor_bytes(rtype)
                    if body.startswith(f"{kind}-start("):
                        nbytes //= 2        # tuple (operand, result)
                    src = sum(_effective_bytes(o, types, defs)
                              for o in _operand_names(body))
                    if src:
                        nbytes = min(nbytes, src)
                    n = _group_size(ln, default_group)
                    moved = nbytes * _ring_factor(kind, n) * m
                    stats.coll_per_chip_bytes += moved
                    counts[kind] += m
                    cbytes[kind] += moved
                    matched_coll = True
                    break
            if matched_coll:
                continue

            # --- flops ---
            if body.startswith("dot("):
                f = _dot_flops(ln, rtype, types)
                if f:
                    stats.flops += f * m
            elif body.startswith("convolution("):
                stats.flops += 2.0 * _tensor_bytes(rtype) * m  # coarse

            # --- HBM traffic (reads + writes) ---
            if any(body.startswith(op) for op in _SKIP_OPS):
                continue
            if body.startswith("dynamic-update-slice("):
                stats.bytes_traffic += dus_traffic(ln) * m
                continue
            if body.startswith("dynamic-slice("):
                stats.bytes_traffic += 2.0 * _tensor_bytes(rtype) * m
                continue
            if body.startswith("fusion("):
                mcall = re.search(r"calls=%?([\w\.\-]+)", ln)
                if mcall and mcall.group(1) in comps:
                    stats.bytes_traffic += _fusion_traffic(
                        comps[mcall.group(1)], types) * m
                    continue
            reads = sum(_effective_bytes(o, types, defs)
                        for o in _operand_names(body))
            stats.bytes_traffic += (reads + _tensor_bytes(rtype)) * m

    stats.coll_op_counts = dict(counts)
    stats.coll_op_bytes = dict(cbytes)
    return stats


# Backwards-compatible alias used by dryrun.py
def collective_stats(hlo_text: str, default_group: int) -> HLOStats:
    return analyze(hlo_text, default_group)
