"""input_specs: ShapeDtypeStruct stand-ins for every step argument.

Weak-type-correct, shardable, zero device allocation -- the dry-run lowers
jit(step) against these.  One function per step kind:

* train:   (params, opt_state, batch)
* prefill: (params, batch, cache)
* decode:  (params, tokens, cache, pos)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchSpec
from repro.models.api import LMConfig, ShapeCfg
from repro.models.transformer import LM
from repro.optim import AdamW

SDS = jax.ShapeDtypeStruct


def batch_struct(cfg: LMConfig, shape: ShapeCfg, mode: str) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if mode == "decode":
        if cfg.frontend == "audio_stub":
            batch["tokens"] = SDS((B, 1, cfg.d_model), jnp.bfloat16)
        else:
            batch["tokens"] = SDS((B, 1), jnp.int32)
        return batch
    if cfg.frontend == "audio_stub":
        batch["embeds"] = SDS((B, S, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = SDS((B, S), jnp.int32)
    if cfg.frontend == "vision_stub":
        batch["img_embeds"] = SDS((B, cfg.n_img_tokens, cfg.d_model),
                                  jnp.bfloat16)
    if mode == "train":
        batch["labels"] = SDS((B, S), jnp.int32)
    return batch


def params_struct(model: LM, dtype=jnp.bfloat16) -> Any:
    return jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), dtype=dtype))


def opt_struct(params_sds: Any, optimizer: AdamW) -> Any:
    return jax.eval_shape(optimizer.init, params_sds)


def cache_struct(model: LM, batch: int, max_len: int,
                 dtype=jnp.bfloat16, kv_bits=None) -> Any:
    return jax.eval_shape(
        lambda: model.init_cache(batch, max_len, dtype=dtype,
                                 kv_bits=kv_bits))


def step_structs(spec: ArchSpec, shape: ShapeCfg, optimizer: AdamW,
                 dtype=jnp.bfloat16, cfg_override=None, quant_serve=False,
                 kv_bits=None) -> Tuple[Any, ...]:
    """All argument ShapeDtypeStructs for the step of this shape's mode.

    quant_serve: params in int8 serving layout ({"q", "s"} per matmul
    weight); kv_bits=8: int8 KV cache with per-(pos, head) scales.
    """
    cfg = cfg_override or spec.config
    model = LM(cfg)
    p = params_struct(model, dtype)
    if quant_serve:
        p = jax.eval_shape(model.quantize_params_int8, p)
    if shape.mode == "train":
        return (p, opt_struct(p, optimizer),
                batch_struct(cfg, shape, "train"))
    if shape.mode == "prefill":
        return (p, batch_struct(cfg, shape, "prefill"),
                cache_struct(model, shape.global_batch, shape.seq_len, dtype,
                             kv_bits=kv_bits))
    # decode: one new token against a seq_len KV cache
    return (p, batch_struct(cfg, shape, "decode")["tokens"],
            cache_struct(model, shape.global_batch, shape.seq_len, dtype,
                         kv_bits=kv_bits),
            SDS((), jnp.int32))
