"""jit-able train / prefill / decode steps with their sharding trees."""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.api import LMConfig, ShapeCfg
from repro.models.transformer import LM
from repro.optim import AdamW
from repro.sharding import specs as sh
from repro.sharding.ctx import sharding_rules


def hidden_rules(mesh) -> dict:
    """Activation constraints model code applies at block boundaries."""
    if "pod" in mesh.shape:
        return {"hidden": P(("pod", "data"), None, None)}
    return {"hidden": P("data", None, None)}


def moe_local_rules(mesh) -> dict:
    """Local MoE dispatch: pin the per-DP-shard token groups so routing
    cumsums/scatters stay shard-local (models/layers.moe_ffn).  Right for
    small-expert MoE (granite) where replicating experts across DP is cheap;
    large-expert MoE (jamba) keeps EP sharding instead."""
    dp = ("pod", "data") if "pod" in mesh.shape else "data"
    return {"moe_group": P(dp, None, None)}


def make_train_step(model: LM, optimizer: AdamW, lr: float = 1e-4,
                    compress_pod: bool = False, mesh=None,
                    batch_sds=None, remat="full") -> Callable:
    """Standard step, or (compress_pod) a step whose only cross-pod
    communication is the int8-compressed gradient exchange: loss/grad runs
    under shard_map manual over "pod" (auto over data/model), each pod sees
    its local batch, and sharding/collectives.compressed_allreduce averages
    the gradients."""
    remat_arg = "dots" if remat == "dots" else True
    def train_step(params, opt_state, batch):
        if compress_pod:
            from repro.sharding.collectives import compressed_allreduce

            def local(params, batch):
                loss, grads = jax.value_and_grad(
                    lambda p: model.loss(p, batch, remat=remat_arg))(params)
                out = compressed_allreduce(
                    {"g": grads, "l": loss}, "pod")
                return out["l"], out["g"]

            in_specs = (jax.tree.map(lambda _: P(), params),
                        jax.tree.map(lambda _: P("pod"), batch))
            loss, grads = jax.shard_map(
                local, mesh=mesh, in_specs=in_specs,
                out_specs=(P(), jax.tree.map(lambda _: P(), params)),
                axis_names={"pod"}, check_vma=False)(params, batch)
        else:
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, remat=remat_arg))(params)
        params, opt_state, om = optimizer.update(params, grads, opt_state,
                                                 lr=lr)
        return params, opt_state, {"loss": loss, **om}
    return train_step


def make_prefill_step(model: LM) -> Callable:
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)
    return prefill_step


def make_decode_step(model: LM) -> Callable:
    def decode_step(params, tokens, cache, pos):
        return model.decode_step(params, tokens, cache, pos)
    return decode_step


def shardings_for(spec_structs: Tuple[Any, ...], mode: str, cfg: LMConfig,
                  shape: ShapeCfg, mesh):
    """(in_shardings, out_shardings) PartitionSpec trees for jit."""
    long_ctx = shape.name == "long_500k" or (
        shape.mode == "decode" and
        shape.global_batch % max(mesh.shape.get("data", 1), 1) != 0)
    if mode == "train":
        p_sds, o_sds, b_sds = spec_structs
        ps = sh.param_specs(p_sds, mesh, cfg)
        os_ = sh.opt_specs(o_sds, ps, mesh)
        bs = sh.batch_specs(b_sds, mesh)
        return (ps, os_, bs), (ps, os_, None)
    if mode == "prefill":
        p_sds, b_sds, c_sds = spec_structs
        ps = sh.param_specs(p_sds, mesh, cfg)
        bs = sh.batch_specs(b_sds, mesh)
        cs = sh.cache_specs(c_sds, cfg, mesh, long_context=long_ctx)
        return (ps, bs, cs), (None, cs)
    p_sds, t_sds, c_sds, _ = spec_structs
    ps = sh.param_specs(p_sds, mesh, cfg)
    ts = sh.batch_specs(t_sds, mesh)
    cs = sh.cache_specs(c_sds, cfg, mesh, long_context=long_ctx)
    return (ps, ts, cs, None), (None, cs)
