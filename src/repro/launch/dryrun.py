import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this module -- jax
locks the device count on first init, and the production meshes need 512
placeholder host devices.  Do not set this flag anywhere else (smoke tests
and benchmarks must see 1 device).

Per cell this driver:
  1. builds the full-size model config and ShapeDtypeStruct inputs
     (launch/specs.py -- no allocation),
  2. jits the train/prefill/decode step with the production shardings,
  3. .lower().compile(), then records memory_analysis(), cost_analysis(),
     and the post-SPMD collective traffic (launch/hlo.py) to JSON for
     EXPERIMENTS.md section Dry-run / section Roofline.

Usage:
  python -m repro.launch.dryrun --arch mamba2-780m --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out results/dryrun]
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCHS, get
from repro.launch import hlo as hlo_mod
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import step_structs
from repro.launch.steps import (hidden_rules, make_decode_step,
                                make_prefill_step, make_train_step,
                                shardings_for)
from repro.models.api import SHAPES, shape_by_name
from repro.models.transformer import LM
from repro.optim import AdamW
from repro.sharding.ctx import sharding_rules
from repro.sharding.specs import to_named


def _mem_analysis_dict(compiled):
    out = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
    except Exception as e:          # CPU backend may not implement it
        out["error"] = repr(e)
    return out


def _cost_analysis_dict(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and not k.startswith("util")}
    except Exception as e:
        return {"error": repr(e)}


def _apply_opts(cfg, opts, mesh):
    """Optimization-variant transforms (EXPERIMENTS.md §Perf hillclimbs)."""
    import dataclasses as dc
    from jax.sharding import PartitionSpec as P
    rules = hidden_rules(mesh)
    if "ep_pad" in opts and cfg.moe is not None:
        dsz = mesh.shape.get("data", 1)
        pad = -(-cfg.moe.n_experts // dsz) * dsz
        cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, pad_to=pad))
    if "moe_local" in opts and cfg.moe is not None:
        from repro.launch.steps import moe_local_rules
        cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, local_dispatch=True))
        rules.update(moe_local_rules(mesh))
    if "logits_sharded" in opts:
        dp = ("pod", "data") if "pod" in mesh.shape else "data"
        rules["logits"] = P(dp, None, "model")
    if "weight_gather" in opts:
        # weight-stationary: gather FSDP shards at use, keep TP shard; stops
        # GSPMD from resharding full activations every matmul
        rules["w_col"] = P(None, "model")
        rules["w_row"] = P("model", None)
    if "compress_pod" in opts:
        # model code runs inside shard_map manual over "pod": activation
        # constraints must only name auto axes
        rules["hidden"] = P("data", None, None)
        if "logits" in rules:
            rules["logits"] = P("data", None, "model")
    return cfg, rules


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             out_dir: pathlib.Path, keep_hlo: bool = False,
             opts: tuple = ()) -> dict:
    spec = get(arch_id)
    shape = shape_by_name(shape_name)
    tag = "" if not opts else "__" + "+".join(sorted(opts))
    result = {"arch": arch_id, "shape": shape_name,
              "mesh": mesh_kind + tag, "opts": sorted(opts),
              "mode": shape.mode, "status": "skip"}
    out_dir.mkdir(parents=True, exist_ok=True)
    if shape_name in spec.skip_shapes:
        result["reason"] = spec.skip_reason
        _write(out_dir, result)
        return result

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        cfg, rules = _apply_opts(spec.config, set(opts), mesh)
        model = LM(cfg)
        optimizer = AdamW(state_bits=8)
        structs = step_structs(
            spec, shape, optimizer, cfg_override=cfg,
            quant_serve="quant_serve" in opts,
            kv_bits=8 if "kv8" in opts else None)
        in_specs, out_specs = shardings_for(structs, shape.mode, cfg, shape,
                                            mesh)
        if "compress_pod" in opts and shape.mode == "train" and \
                mesh_kind == "multi":
            # shard_map is manual over "pod" only: the batch must enter
            # sharded over "pod" alone (data-axis sharding is re-derived by
            # GSPMD inside the auto region)
            from jax.sharding import PartitionSpec as P
            in_specs = (in_specs[0], in_specs[1],
                        jax.tree.map(lambda s: P("pod"), in_specs[2],
                                     is_leaf=lambda x: isinstance(x, P)))
        if shape.mode == "train":
            step = make_train_step(
                model, optimizer,
                compress_pod=("compress_pod" in opts and
                              mesh_kind == "multi"),
                mesh=mesh,
                remat="dots" if "remat_dots" in opts else "full")
        elif shape.mode == "prefill":
            step = make_prefill_step(model)
        else:
            step = make_decode_step(model)

        with mesh, sharding_rules(mesh, rules):
            jitted = jax.jit(step,
                             in_shardings=to_named(in_specs, mesh),
                             out_shardings=to_named(out_specs, mesh))
            lowered = jitted.lower(*structs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        n_dev = mesh.size
        result.update(status="ok", devices=n_dev,
                      lower_s=round(t_lower, 1),
                      compile_s=round(t_compile, 1))
        result["memory_analysis"] = _mem_analysis_dict(compiled)
        result["cost_analysis"] = _cost_analysis_dict(compiled)
        hlo_text = compiled.as_text()
        result["hlo_chars"] = len(hlo_text)
        cs = hlo_mod.analyze(hlo_text, default_group=n_dev)
        result["hlo_stats"] = {
            "flops_per_device": cs.flops,
            "bytes_traffic_per_device": cs.bytes_traffic,
        }
        result["collectives"] = {
            "per_chip_bytes": cs.coll_per_chip_bytes,
            "op_counts": cs.coll_op_counts,
            "op_bytes": cs.coll_op_bytes,
            "warnings": cs.parse_warnings[:10],
        }
        if keep_hlo:
            (out_dir / f"{arch_id}__{shape_name}__{mesh_kind}.hlo.txt"
             ).write_text(hlo_text)
        del compiled, lowered, hlo_text
    except Exception as e:
        result.update(status="fail", error=repr(e),
                      traceback=traceback.format_exc()[-4000:])
    result["wall_s"] = round(time.time() - t0, 1)
    _write(out_dir, result)
    return result


def _write(out_dir: pathlib.Path, result: dict):
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{result['arch']}__{result['shape']}__{result['mesh']}.json"
    (out_dir / name).write_text(json.dumps(result, indent=1, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma list: logits_sharded,remat_dots,ep_pad,"
                         "quant_serve,kv8,compress_pod")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    opts = tuple(o for o in args.opt.split(",") if o)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or args.shape is None) \
        else [args.shape]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                f = out_dir / f"{arch}__{shape}__{mesh_kind}.json"
                if args.skip_done and f.exists():
                    prev = json.loads(f.read_text())
                    if prev.get("status") in ("ok", "skip"):
                        print(f"[cached] {arch} {shape} {mesh_kind}: "
                              f"{prev['status']}", flush=True)
                        continue
                r = run_cell(arch, shape, mesh_kind, out_dir,
                             keep_hlo=args.keep_hlo, opts=opts)
                msg = r["status"]
                if r["status"] == "ok":
                    fl = r["cost_analysis"].get("flops", float("nan"))
                    msg += (f" compile={r['compile_s']}s flops={fl:.3g} "
                            f"coll={r['collectives']['per_chip_bytes']:.3g}B")
                elif r["status"] == "fail":
                    n_fail += 1
                    msg += f" error={r['error'][:200]}"
                print(f"{arch} {shape} {mesh_kind}: {msg}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
