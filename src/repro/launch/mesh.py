"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state -- the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256 chips/pod) single-pod mesh, or 2 pods = 512 chips.

    Axes: "data" carries FSDP + batch DP (+ EP for MoE), "model" carries TP;
    "pod" (multi-pod) is pure DP with gradient all-reduce across pods.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the local device (smoke tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
