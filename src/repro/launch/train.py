"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \
        --steps 20

--smoke runs the reduced same-family config on the local device; full-size
configs are exercised via the dry-run (this container has one CPU core).
On a real cluster, drop --smoke and point --mesh at single/multi to jit the
train step against the production mesh (same code path the dry-run proves).
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.configs import get
from repro.data import TokenStream
from repro.models import LM
from repro.optim import AdamW
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--state-bits", type=int, default=32, choices=[8, 32])
    args = ap.parse_args()

    spec = get(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stream = TokenStream(vocab=cfg.vocab)

    def data_fn(step):
        b = stream.batch(step, args.batch, args.seq)
        if cfg.frontend == "audio_stub":
            rng = np.random.default_rng(step)
            return {"embeds": rng.normal(size=(args.batch, args.seq,
                                               cfg.d_model)).astype("f4"),
                    "labels": b["labels"]}
        if cfg.frontend == "vision_stub":
            rng = np.random.default_rng(step)
            b["img_embeds"] = rng.normal(
                size=(args.batch, cfg.n_img_tokens,
                      cfg.d_model)).astype("f4")
        return b

    ckpt = args.ckpt or tempfile.mkdtemp(prefix=f"{args.arch}_ckpt_")
    trainer = Trainer(model, params, AdamW(lr=1e-3,
                                           state_bits=args.state_bits),
                      data_fn, ckpt,
                      TrainConfig(total_steps=args.steps,
                                  ckpt_every=max(args.steps // 2, 1),
                                  lr=1e-3, log_every=max(args.steps // 5, 1)))
    out = trainer.run()
    for h in out["history"]:
        print(f"step {h['step']:5d} loss {h['loss']:.4f} "
              f"gnorm {h['grad_norm']:.3f}")
    print(f"checkpoints in {ckpt}")


if __name__ == "__main__":
    main()
