"""Linear (uniform, symmetric) quantization with per-channel bit-widths.

The paper's quantizer: a weight output channel with QBN ``b`` is mapped onto the
integer grid {-(2^(b-1)-1), ..., 2^(b-1)-1} with a per-channel scale
``s = amax / (2^(b-1)-1)``.  ``b = 0`` prunes the channel, ``b >= FULL_BITS``
is a pass-through (full precision).  All functions are jit-safe and accept
*vector* bit-widths so a single call fake-quantizes a tensor whose channels
carry different QBNs -- the kernel-wise regime AutoQ searches over.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Bit-widths at or above this behave as full precision (f32 mantissa is 24
# bits; >=24-bit fixed point is indistinguishable for our purposes).
FULL_BITS = 24


def _levels(bits: jnp.ndarray) -> jnp.ndarray:
    """Number of positive quantization levels for signed symmetric quant."""
    bits = jnp.asarray(bits, jnp.float32)
    return jnp.maximum(2.0 ** (bits - 1.0) - 1.0, 1.0)


def fake_quant(x: jnp.ndarray, bits, axis: int | None = None) -> jnp.ndarray:
    """Quantize-dequantize ``x`` at ``bits`` (scalar or per-channel vector).

    Args:
      x: tensor to quantize.
      bits: scalar, or vector of shape ``x.shape[axis]`` with per-channel QBNs.
      axis: channel axis for per-channel scales (None -> per-tensor).
    """
    x = jnp.asarray(x)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if axis is None:
        amax = jnp.max(jnp.abs(xf))
        b = jnp.asarray(bits, jnp.float32)
    else:
        axis = axis % xf.ndim
        red = tuple(d for d in range(xf.ndim) if d != axis)
        amax = jnp.max(jnp.abs(xf), axis=red, keepdims=True)
        b = jnp.asarray(bits, jnp.float32)
        if b.ndim > 0:  # per-channel vector -> broadcastable shape
            shape = [1] * xf.ndim
            shape[axis] = xf.shape[axis]
            b = b.reshape(shape)
    lv = _levels(b)
    scale = jnp.where(amax > 0, amax / lv, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -lv, lv) * scale
    out = jnp.where(b <= 0.5, 0.0, jnp.where(b >= FULL_BITS, xf, q))
    return out.astype(dtype)


def fake_quant_per_channel(w: jnp.ndarray, bits_per_channel, axis: int = -1):
    """Per-output-channel fake quantization (the paper's weight quantizer)."""
    return fake_quant(w, bits_per_channel, axis=axis)


def fake_quant_per_token(x: jnp.ndarray, bits) -> jnp.ndarray:
    """Row-wise (per-token) fake quantization: one dynamic scale per
    leading-index row, amax over the last (feature) axis.

    This is the serving-side activation quantizer: each token's activation
    is scaled by its own amax, so the result for a token is independent of
    whatever else shares the batch.  (A per-tensor scale would couple
    continuous-batching decode lanes: admitting a new request would change
    every other in-flight sequence's quantization grid.)  ``bits`` is a
    scalar; <= 0.5 prunes, >= FULL_BITS passes through, matching
    :func:`fake_quant`.
    """
    x = jnp.asarray(x)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    b = jnp.asarray(bits, jnp.float32)
    lv = _levels(b)
    scale = jnp.where(amax > 0, amax / lv, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -lv, lv) * scale
    out = jnp.where(b <= 0.5, 0.0, jnp.where(b >= FULL_BITS, xf, q))
    return out.astype(dtype)


@jax.custom_vjp
def ste_fake_quant(x: jnp.ndarray, bits: jnp.ndarray, axis: int):
    """Fake quant with a straight-through gradient estimator (QAT forward)."""
    return fake_quant(x, bits, axis=axis)


def _ste_fwd(x, bits, axis):
    return fake_quant(x, bits, axis=axis), None


def _ste_bwd(_, g):
    return (g, None, None)


ste_fake_quant.defvjp(_ste_fwd, _ste_bwd)


def quant_pack_int8(w: jnp.ndarray, bits, axis: int = -1):
    """Quantize to a *stored* int8 representation + per-channel f32 scales.

    This is the deployment path (what the Pallas ``quant_matmul`` kernel
    consumes): channels with QBN in [1, 8] round to int8 on their own grid,
    QBN 0 stores zeros, QBN > 8 falls back to the bf16 path at a higher layer
    (the packer clamps to 8 and the caller tracks the overflow set).

    Returns (q_int8, scale, eff_bits) with ``scale`` shaped like the channel
    axis and broadcastable against ``q``.
    """
    w = jnp.asarray(w, jnp.float32)
    axis = axis % w.ndim
    red = tuple(d for d in range(w.ndim) if d != axis)
    amax = jnp.max(jnp.abs(w), axis=red, keepdims=True)
    b = jnp.asarray(bits, jnp.float32)
    if b.ndim > 0:
        shape = [1] * w.ndim
        shape[axis] = w.shape[axis]
        b = b.reshape(shape)
    b = jnp.clip(b, 0.0, 8.0)
    lv = _levels(b)
    scale = jnp.where(amax > 0, amax / lv, 1.0)
    q = jnp.clip(jnp.round(w / scale), -lv, lv)
    q = jnp.where(b <= 0.5, 0.0, q)
    return q.astype(jnp.int8), scale.astype(jnp.float32), b


def dequant_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quant_pack_int8` (reference; kernel fuses this)."""
    return q.astype(jnp.float32) * scale


def quant_pack_sub8(w: jnp.ndarray, bits, axis: int = -1):
    """Quantize to the *bucketed sub-byte* stored layout + per-channel scales.

    The deployment path that realizes searched sub-byte QBNs as actual HBM
    bytes (kernels/pack.py holds the container; kernels/ops.py the matmuls):
    each output channel is routed by its QBN into a storage bucket --

        b <= 0   pruned     no storage (reconstructs as zeros)
        b <= 2   int2       crumb-packed along K, 4 values/byte
        b <= 4   int4       nibble-packed along K, 2 values/byte
        b <= 8   int8       1 byte/value (same grid as quant_pack_int8)
        b >  8   full       bf16 passthrough (2 bytes/value)

    Channels quantize on their *own* grid (levels = 2^(b-1)-1, scale =
    amax/levels, amax reduced over all non-channel dims -- identical to
    fake_quant, so the packed store round-trips to the fake-quant numerics
    for b <= 8).  Because storage width >= QBN within each bucket, every
    quantized value fits its bucket's field exactly.

    w: (..., K, N) with output channels **last** (axis must be the last
    axis); bits: scalar or (N,) per-channel QBNs.  Bucket membership is
    static (numpy), so this is a load-time transform, not a jit-traceable
    op.  Returns a :class:`repro.kernels.pack.PackedWeight`.
    """
    # lazy import: kernels.fake_quant imports FULL_BITS from this module
    from repro.kernels.pack import (PackedWeight, STORE_BITS, bucket_of_bits,
                                    pack_sub8)
    w = jnp.asarray(w)
    assert w.ndim >= 2, w.shape
    assert axis % w.ndim == w.ndim - 1, \
        "packed layout requires output channels on the last axis"
    n, k = w.shape[-1], w.shape[-2]
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=tuple(range(w.ndim - 1)))     # (n,)
    b = np.rint(np.broadcast_to(
        np.asarray(bits, np.float32), (n,))).astype(np.int64)
    members: dict = {}
    for c in range(n):
        members.setdefault(bucket_of_bits(b[c]), []).append(c)
    parts, buckets = [], []
    for name in ("pruned", "int2", "int4", "int8", "full"):
        idx = members.get(name)
        if not idx:
            continue
        buckets.append((name, tuple(idx)))
        if name == "pruned":
            # zero-width sentinel keeps the leading (stack) dims observable
            # even when every channel is pruned, and scans like any child
            parts.append((jnp.zeros(w.shape[:-2] + (k, 0), jnp.int8),))
            continue
        idx_a = jnp.asarray(idx)
        cols = wf[..., idx_a]
        if name == "full":
            parts.append((cols.astype(jnp.bfloat16),))
            continue
        lv = _levels(jnp.asarray(b[idx], jnp.float32))             # (nb,)
        am = amax[idx_a]
        sc = jnp.where(am > 0, am / lv, 1.0)
        q = jnp.clip(jnp.round(cols / sc), -lv, lv).astype(jnp.int32)
        data = q.astype(jnp.int8) if name == "int8" else \
            pack_sub8(q, STORE_BITS[name], axis=-2)
        # scale broadcast over leading (stack) dims so every child of the
        # pytree scans with the weight it belongs to
        scale = jnp.broadcast_to(sc, w.shape[:-2] + (len(idx),))
        parts.append((data, scale.astype(jnp.float32)))
    return PackedWeight(parts=tuple(parts), k=k, n=n, buckets=tuple(buckets),
                        out_dtype=str(w.dtype))
