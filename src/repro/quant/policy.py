"""Quantization policy containers and the quantizable-layer graph.

A :class:`QuantizableGraph` is the model-agnostic view the AutoQ agent works
on: an ordered list of quantizable layers, each with channel counts, MAC
counts and a path into the parameter pytree.  A :class:`QuantPolicy` assigns a
bit-width vector (one entry per *channel group*) to every layer's weights and
a scalar bit-width to every layer's activations -- exactly the paper's action
space (the paper itself collapses activation channels per FC layer; all LM
layers are FC-like, so activations carry one QBN per layer).

Channel *groups*: the paper's CNNs have at most a few thousand channels per
layer; LM layers can have 24k+.  Groups of contiguous channels share a QBN so
the episode length stays O(1k) for billion-parameter models.  ``group_size=1``
recovers the paper's exact per-channel regime (used for the CNN repro).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, List, Sequence, Tuple

import numpy as np


class QuantMode(enum.Enum):
    QUANT = "quant"          # linear fixed point (QBN)
    BINARIZE = "binarize"    # multi-bit binary codes (BBN)


class Granularity(enum.Enum):
    NETWORK = "network"      # one QBN for the whole net      (X-N in the paper)
    LAYER = "layer"          # one QBN per layer              (X-L)
    CHANNEL = "channel"      # one QBN per output-chan group  (X-C, the paper)


@dataclasses.dataclass(frozen=True)
class LayerInfo:
    """One quantizable layer (conv / linear / expert matrix)."""
    name: str
    kind: str                 # "conv" | "linear" | "expert" | "unembed"
    c_in: int
    c_out: int
    k: int                    # spatial kernel size (1 for linear)
    stride: int               # conv stride (1 for linear)
    macs: float               # MACs for one forward pass at the reference shape
    numel: int                # weight element count
    param_path: Tuple[Any, ...]   # keys into the params pytree
    channel_axis: int         # output-channel axis of the weight tensor
    n_groups: int             # number of channel groups (actions for this layer)

    @property
    def group_size(self) -> int:
        return max(1, self.c_out // self.n_groups)


@dataclasses.dataclass
class QuantizableGraph:
    """Ordered quantizable layers + totals; built per model by extractors."""
    layers: List[LayerInfo]

    @property
    def total_macs(self) -> float:
        return float(sum(l.macs for l in self.layers))

    @property
    def total_numel(self) -> int:
        return int(sum(l.numel for l in self.layers))

    @property
    def total_groups(self) -> int:
        return int(sum(l.n_groups for l in self.layers))

    def layer(self, name: str) -> LayerInfo:
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(name)


@dataclasses.dataclass
class QuantPolicy:
    """Bit assignment for a whole network.

    weight_bits[name] is a float/int vector of length layer.n_groups (expanded
    to per-channel at application time); act_bits[name] is a scalar.
    """
    mode: QuantMode
    weight_bits: Dict[str, np.ndarray]
    act_bits: Dict[str, float]

    # ------------------------------------------------------------------ ctors
    @staticmethod
    def uniform(graph: QuantizableGraph, bits: float,
                mode: QuantMode = QuantMode.QUANT,
                act_bits: float | None = None) -> "QuantPolicy":
        act = bits if act_bits is None else act_bits
        return QuantPolicy(
            mode=mode,
            weight_bits={l.name: np.full(l.n_groups, float(bits)) for l in graph.layers},
            act_bits={l.name: float(act) for l in graph.layers},
        )

    @staticmethod
    def per_layer(graph: QuantizableGraph, wbits: Sequence[float],
                  abits: Sequence[float],
                  mode: QuantMode = QuantMode.QUANT) -> "QuantPolicy":
        assert len(wbits) == len(graph.layers) == len(abits)
        return QuantPolicy(
            mode=mode,
            weight_bits={l.name: np.full(l.n_groups, float(b))
                         for l, b in zip(graph.layers, wbits)},
            act_bits={l.name: float(a) for l, a in zip(graph.layers, abits)},
        )

    def copy(self) -> "QuantPolicy":
        return QuantPolicy(
            mode=self.mode,
            weight_bits={k: v.copy() for k, v in self.weight_bits.items()},
            act_bits=dict(self.act_bits),
        )

    # ------------------------------------------------------------- aggregates
    def avg_weight_bits(self, graph: QuantizableGraph) -> float:
        """Element-weighted mean weight QBN/BBN across the network."""
        num = den = 0.0
        for l in graph.layers:
            per_group_numel = l.numel / l.n_groups
            num += float(np.sum(self.weight_bits[l.name])) * per_group_numel
            den += l.numel
        return num / max(den, 1.0)

    def avg_act_bits(self, graph: QuantizableGraph) -> float:
        """MAC-weighted mean activation QBN/BBN (matches paper reporting)."""
        num = sum(self.act_bits[l.name] * l.macs for l in graph.layers)
        return float(num / max(graph.total_macs, 1.0))

    def logic_ops(self, graph: QuantizableGraph) -> float:
        """m(N): AND (quant) / XNOR (binarize) ops for one inference.

        A MAC between a qw-bit weight and a qa-bit activation costs qw*qa
        bit-level logic ops (serial-parallel multiplier [Gnanasekaran 1985] for
        quantization; bit-plane XNOR count for binarization) -- the paper's
        logic_t accounting.
        """
        total = 0.0
        for l in graph.layers:
            mean_wbits = float(np.mean(self.weight_bits[l.name]))
            total += l.macs * mean_wbits * self.act_bits[l.name]
        return total

    def model_size_bits(self, graph: QuantizableGraph) -> float:
        """Stored weight bits (p(N)*32*numel in paper terms)."""
        total = 0.0
        for l in graph.layers:
            per_group_numel = l.numel / l.n_groups
            total += float(np.sum(self.weight_bits[l.name])) * per_group_numel
        return total

    def expand_weight_bits(self, layer: LayerInfo) -> np.ndarray:
        """Per-group vector -> per-channel vector of length c_out."""
        g = self.weight_bits[layer.name]
        reps = int(np.ceil(layer.c_out / layer.n_groups))
        return np.repeat(np.asarray(g, np.float32), reps)[: layer.c_out]
