"""Apply a QuantPolicy to a parameter pytree / to activations.

Weights are fake-quantized once per candidate policy (outside the forward);
activations are quantized inside the forward via :func:`quantize_activation`,
which models consult through a ``quant_ctx`` dict threaded into ``apply``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp
import numpy as np

from repro.quant.binarize import fake_binarize_per_channel
from repro.quant.linear_quant import (fake_quant, fake_quant_per_channel,
                                      quant_pack_sub8)
from repro.quant.policy import QuantMode, QuantPolicy, QuantizableGraph


def _get_path(tree: Any, path):
    node = tree
    for key in path:
        node = node[key]
    return node


def _set_path(tree: Any, path, value):
    """Functionally set ``tree[path] = value`` for nested dicts/tuples."""
    if not path:
        return value
    key = path[0]
    if isinstance(tree, (tuple, list)):
        items = list(tree)
        items[key] = _set_path(tree[key], path[1:], value)
        return type(tree)(items)
    new = dict(tree)
    new[key] = _set_path(tree[key], path[1:], value)
    return new


def apply_policy_to_params(params: Any, graph: QuantizableGraph,
                           policy: QuantPolicy) -> Any:
    """Return a new params pytree with every searched weight fake-quantized.

    Works for stacked (scan) layouts too: if the stored weight has one more
    leading dim than the LayerInfo expects, the quantizer broadcasts over it
    (per-channel scales are then per (stack, channel)).
    """
    out = params
    for layer in graph.layers:
        w = _get_path(params, layer.param_path)
        bits = jnp.asarray(policy.expand_weight_bits(layer))
        axis = layer.channel_axis
        if policy.mode == QuantMode.QUANT:
            qw = fake_quant_per_channel(w, bits, axis=axis)
        else:
            qw = fake_binarize_per_channel(w, bits, axis=axis).astype(w.dtype)
        out = _set_path(out, layer.param_path, qw)
    return out


def apply_policy_packed(params: Any, graph: QuantizableGraph,
                        policy: QuantPolicy) -> Any:
    """Deployment transform: searched weights -> bucketed sub-byte stores.

    Like :func:`apply_policy_to_params`, but instead of fake-quantized f32
    tensors every searched weight leaf becomes a
    :class:`repro.kernels.pack.PackedWeight` -- channels with QBN <= 4
    bit-packed along K, 5..8 int8, > 8 bf16 passthrough -- so weight HBM
    bytes actually track the searched policy.  ``models.layers.deq`` unpacks
    at use; stacked (scan) weights ride through unchanged because every
    PackedWeight child keeps the leading stack dim.
    """
    assert policy.mode == QuantMode.QUANT, \
        "packed serving implements linear quantization (QBN) only"
    out = params
    for layer in graph.layers:
        w = _get_path(params, layer.param_path)
        bits = policy.expand_weight_bits(layer)
        assert layer.channel_axis % w.ndim == w.ndim - 1, layer.name
        out = _set_path(out, layer.param_path, quant_pack_sub8(w, bits))
    return out


def quantize_activation(x: jnp.ndarray, quant_ctx: Dict[str, Any] | None,
                        name: str) -> jnp.ndarray:
    """Activation fake-quant hook used inside model forwards.

    ``quant_ctx`` maps layer name -> activation bits (scalar); missing name or
    None ctx means full precision.  Activation quantization is per-tensor
    (the paper assigns one QBN to all activation channels of an FC layer).
    """
    if quant_ctx is None:
        return x
    bits = quant_ctx.get(name)
    if bits is None:
        return x
    return fake_quant(x, bits, axis=None)


def policy_metrics(graph: QuantizableGraph, policy: QuantPolicy,
                   full_bits: float = 32.0) -> Dict[str, float]:
    """NetScore ingredients for a policy: p(N), m(N) and reduction ratios."""
    logic_full = graph.total_macs * full_bits * full_bits
    logic = policy.logic_ops(graph)
    size_full = graph.total_numel * full_bits
    size = policy.model_size_bits(graph)
    return {
        "avg_weight_bits": policy.avg_weight_bits(graph),
        "avg_act_bits": policy.avg_act_bits(graph),
        "logic_ops": logic,
        "logic_ratio": logic / max(logic_full, 1.0),
        "model_bits": size,
        "size_ratio": size / max(size_full, 1.0),
        "p": policy.avg_weight_bits(graph) / full_bits,
        "m": logic,
    }
