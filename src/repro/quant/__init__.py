"""Quantization substrate: linear per-channel quantization, multi-bit
binarization, policy containers, and policy application.

This package implements the two compression back-ends the paper searches over:

* linear (uniform symmetric) quantization [Zhou et al., INQ] with a bit-width
  (QBN) per weight output channel, 0 = channel pruned, >=32 = full precision;
* multi-bit binarization [Lin et al., ABC-Net-style]: W ~= sum_m alpha_m B_m
  with B_m in {-1,+1}, BBN planes per channel.
"""
from repro.quant.linear_quant import (
    fake_quant,
    fake_quant_per_channel,
    ste_fake_quant,
    quant_pack_int8,
    quant_pack_sub8,
)
from repro.quant.binarize import binarize_residual, fake_binarize_per_channel
from repro.quant.policy import (
    Granularity,
    QuantMode,
    QuantPolicy,
    LayerInfo,
    QuantizableGraph,
)
from repro.quant.apply import (
    apply_policy_to_params,
    apply_policy_packed,
    quantize_activation,
    policy_metrics,
)

__all__ = [
    "fake_quant",
    "fake_quant_per_channel",
    "ste_fake_quant",
    "quant_pack_int8",
    "quant_pack_sub8",
    "binarize_residual",
    "fake_binarize_per_channel",
    "Granularity",
    "QuantMode",
    "QuantPolicy",
    "LayerInfo",
    "QuantizableGraph",
    "apply_policy_to_params",
    "apply_policy_packed",
    "quantize_activation",
    "policy_metrics",
]
