"""Multi-bit binarization: W ~= sum_m alpha_m B_m, B_m in {-1, +1}.

Implements the paper's binarization back-end [Lin et al. 2017 style]: greedy
residual binarization (B_m = sign(R_m), alpha_m = E|R_m|) followed by a joint
least-squares refit of the alphas, per output channel.  ``bits = 0`` prunes a
channel; bit-widths are capped at ``MAX_PLANES`` (an 8-plane expansion already
recovers ~all of the signal for weight tensors; the search space above that is
handled by the linear quantizer).

On TPU there is no XNOR/popcount datapath (DESIGN.md section 7); the deployment
form of a binarized matmul is the *bit-plane matmul* y = sum_m alpha_m (x @ B_m)
with B_m stored packed (1 bit/plane) and lifted to int8 sign matrices for the
MXU -- see kernels/binary_matmul.py.
"""
from __future__ import annotations

import jax.numpy as jnp

MAX_PLANES = 8


def binarize_residual(w: jnp.ndarray, planes: int, axis: int = -1):
    """Greedy residual binarization with a joint per-channel alpha refit.

    Args:
      w: weight tensor.
      planes: number of binary planes (static python int, >= 1).
      axis: channel axis; alphas are fit per channel along this axis.

    Returns:
      (B, alpha): B int8 {-1,+1} of shape (planes, *w.shape); alpha f32 of
      shape (planes, *broadcast_shape) where broadcast_shape is 1 everywhere
      except the channel axis.
    """
    planes = int(planes)
    w = jnp.asarray(w, jnp.float32)
    axis_ = axis % w.ndim
    red = tuple(d for d in range(w.ndim) if d != axis_)

    bs, r = [], w
    for _ in range(planes):
        b = jnp.where(r >= 0, 1.0, -1.0)
        a = jnp.mean(jnp.abs(r), axis=red, keepdims=True)
        r = r - a * b
        bs.append(b)
    B = jnp.stack(bs)  # (m, ...)

    # Joint least-squares refit per channel: solve (B B^T) a = B w.
    m = planes
    c = w.shape[axis_]
    wt = jnp.moveaxis(w, axis_, 0).reshape(c, -1)          # (c, k)
    Bt = jnp.moveaxis(B, axis_ + 1, 1).reshape(m, c, -1)   # (m, c, k)
    G = jnp.einsum("mck,nck->cmn", Bt, Bt)                 # (c, m, m)
    rhs = jnp.einsum("mck,ck->cm", Bt, wt)                 # (c, m)
    a = jnp.linalg.solve(G + 1e-6 * jnp.eye(m), rhs[..., None])[..., 0]  # (c, m)

    shape = [1] * w.ndim
    shape[axis_] = c
    alpha = jnp.stack([a[:, i].reshape(shape) for i in range(m)])
    return B.astype(jnp.int8), alpha.astype(jnp.float32)


def reconstruct(B: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """sum_m alpha_m B_m."""
    return jnp.sum(alpha * B.astype(jnp.float32), axis=0)


def fake_binarize_per_channel(w: jnp.ndarray, bits_per_channel, axis: int = -1):
    """Binarize-dequantize with a *vector* of per-channel plane counts.

    Channels with bits 0 are pruned; bits are clipped to [0, MAX_PLANES].  The
    expansion always runs MAX_PLANES greedy planes and masks plane m off for
    channels whose BBN <= m, so a single trace handles heterogeneous BBNs
    (the kernel-wise regime the agent searches).  The greedy residual update is
    unconditional -- only the accumulation is masked -- which makes a channel's
    reconstruction at BBN=b identical to the b-plane greedy expansion.
    """
    w = jnp.asarray(w, jnp.float32)
    axis_ = axis % w.ndim
    red = tuple(d for d in range(w.ndim) if d != axis_)
    shape = [1] * w.ndim
    shape[axis_] = w.shape[axis_]
    bits = jnp.clip(jnp.asarray(bits_per_channel, jnp.float32).reshape(shape),
                    0.0, float(MAX_PLANES))

    out = jnp.zeros_like(w)
    r = w
    for mplane in range(MAX_PLANES):
        b = jnp.where(r >= 0, 1.0, -1.0)
        a = jnp.mean(jnp.abs(r), axis=red, keepdims=True)
        contrib = a * b
        out = out + jnp.where(bits > (mplane + 0.5), contrib, 0.0)
        r = r - contrib
    return out
