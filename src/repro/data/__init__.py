"""Deterministic, shardable synthetic data pipelines."""
from repro.data.synthetic import (SyntheticImages, TokenStream,
                                  make_lm_batch, make_image_batch)

__all__ = ["SyntheticImages", "TokenStream", "make_lm_batch",
           "make_image_batch"]
