"""Synthetic datasets (CIFAR-10 / ImageNet / web-text are unavailable offline).

Two generators, both deterministic in (seed, index) so any host/shard can
reproduce any element without coordination -- the property that makes the
pipeline elastic (a restarted or re-sharded job skips ahead by global step):

* SyntheticImages -- a 10-class image task with class-dependent Gaussian
  texture + frequency patterns; a small CNN reaches >90% accuracy, giving the
  quantization search a meaningful accuracy signal.
* TokenStream -- Zipf-distributed token sequences with a deterministic
  next-token structure (affine-congruential in the class index), so a tiny
  LM trained on it beats the unigram baseline and quantization hurts
  measurably.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticImages:
    n_classes: int = 10
    img_size: int = 16
    channels: int = 3
    seed: int = 0

    def _protos(self):
        rng = np.random.default_rng(self.seed)
        return rng.normal(size=(self.n_classes, self.img_size, self.img_size,
                                self.channels)).astype(np.float32)

    def batch(self, index: int, batch_size: int):
        """Deterministic batch `index`: (x (B,H,W,C), y (B,))."""
        rng = np.random.default_rng((self.seed, index))
        protos = self._protos()
        y = rng.integers(0, self.n_classes, size=batch_size)
        noise = rng.normal(scale=1.0, size=(batch_size, self.img_size,
                                            self.img_size, self.channels))
        x = protos[y] + noise.astype(np.float32)
        return {"x": x.astype(np.float32), "y": y.astype(np.int32)}


@dataclasses.dataclass
class TokenStream:
    vocab: int = 256
    seed: int = 0
    zipf_a: float = 1.2

    def batch(self, index: int, batch_size: int, seq_len: int):
        """Deterministic LM batch: tokens[t+1] = (a*tokens[t] + b) % vocab
        with per-sequence (a, b) drawn from a small set, plus Zipf noise.
        Labels are next tokens (shifted)."""
        rng = np.random.default_rng((self.seed, index))
        a = rng.choice([1, 3, 5, 7], size=(batch_size, 1))
        b = rng.integers(0, self.vocab, size=(batch_size, 1))
        t0 = rng.integers(0, self.vocab, size=(batch_size, 1))
        toks = np.zeros((batch_size, seq_len + 1), np.int64)
        toks[:, :1] = t0
        for t in range(seq_len):
            nxt = (a[:, 0] * toks[:, t] + b[:, 0]) % self.vocab
            flip = rng.random(batch_size) < 0.1
            noise = np.minimum(rng.zipf(self.zipf_a, batch_size) - 1,
                               self.vocab - 1)
            toks[:, t + 1] = np.where(flip, noise, nxt)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def make_image_batch(index: int, batch_size: int, img_size: int = 16,
                     seed: int = 0):
    return SyntheticImages(img_size=img_size, seed=seed).batch(index,
                                                               batch_size)


def make_lm_batch(index: int, batch_size: int, seq_len: int,
                  vocab: int = 256, seed: int = 0):
    return TokenStream(vocab=vocab, seed=seed).batch(index, batch_size,
                                                     seq_len)
