"""Optimizers in pure JAX (no optax offline)."""
from repro.optim.adam import AdamW
from repro.optim.schedule import cosine_warmup

__all__ = ["AdamW", "cosine_warmup"]
