"""AdamW with optional 8-bit block-quantized moments.

At 398B parameters (jamba), fp32 Adam moments are 3.2 TB -- 12.4 GB/chip at
256 chips, which alone blows the v5e 16 GB budget.  The 8-bit mode stores both
moments as int8 with an f32 absmax scale per parameter *row* (last axis is the
quantization block, so the int8 tensors inherit the parameter's PartitionSpec
and the scales shard like the parameter minus its last axis).  The second
moment is stored in the sqrt domain: linear-absmax int8 zeroes small v entries
whose rsqrt then explodes (measured divergence on a quadratic); sqrt halves
the dynamic range in the exponent and recovers fp32-grade convergence.  This
is the paper's own linear quantizer applied to optimizer state -- an on-theme
distributed-training trick (DESIGN.md section 5).

Gradient clipping (global norm) and decoupled weight decay included.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def _q8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize along the last axis: returns (int8, f32 scale[..., 1])."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0
    state_bits: int = 32          # 32 (fp32 moments) or 8 (block-quantized)

    def init(self, params: Any) -> Any:
        if self.state_bits == 8:
            def zero8(p):
                return {"q": jnp.zeros(p.shape, jnp.int8),
                        "s": jnp.zeros(p.shape[:-1] + (1,), jnp.float32)
                        if p.ndim else jnp.zeros((1,), jnp.float32)}
            return {"m": jax.tree.map(zero8, params),
                    "v": jax.tree.map(zero8, params),
                    "t": jnp.zeros((), jnp.int32)}
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(self, params: Any, grads: Any, state: Any,
               lr: Optional[jnp.ndarray] = None) -> Tuple[Any, Any, Any]:
        """Returns (new_params, new_state, metrics)."""
        lr = self.lr if lr is None else lr
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(gf)))
        if self.grad_clip is not None:
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            gf = jax.tree.map(lambda g: g * scale, gf)
        t = state["t"] + 1
        bc1 = 1 - self.b1 ** t.astype(jnp.float32)
        bc2 = 1 - self.b2 ** t.astype(jnp.float32)

        if self.state_bits == 8:
            def upd(p, g, m8, v8):
                m = self.b1 * _dq8(m8["q"], m8["s"]).reshape(p.shape) + \
                    (1 - self.b1) * g
                v_prev = _dq8(v8["q"], v8["s"]).reshape(p.shape) ** 2
                v = self.b2 * v_prev + (1 - self.b2) * g * g
                step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
                if self.weight_decay:
                    step = step + lr * self.weight_decay * \
                        p.astype(jnp.float32)
                mq, ms = _q8(m)
                vq, vs = _q8(jnp.sqrt(v))      # sqrt-domain storage
                return {"__p": (p.astype(jnp.float32) - step).astype(p.dtype),
                        "__m": {"q": mq, "s": ms}, "__v": {"q": vq, "s": vs}}
        else:
            def upd(p, g, m, v):
                m = self.b1 * m + (1 - self.b1) * g
                v = self.b2 * v + (1 - self.b2) * g * g
                step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
                if self.weight_decay:
                    step = step + lr * self.weight_decay * \
                        p.astype(jnp.float32)
                return {"__p": (p.astype(jnp.float32) - step).astype(p.dtype),
                        "__m": m, "__v": v}

        out = jax.tree.map(upd, params, gf, state["m"], state["v"])
        is_cell = lambda x: isinstance(x, dict) and "__p" in x
        new_p = jax.tree.map(lambda o: o["__p"], out, is_leaf=is_cell)
        new_m = jax.tree.map(lambda o: o["__m"], out, is_leaf=is_cell)
        new_v = jax.tree.map(lambda o: o["__v"], out, is_leaf=is_cell)
        new_state = {"m": new_m, "v": new_v, "t": t}
        return new_p, new_state, {"grad_norm": gnorm}
