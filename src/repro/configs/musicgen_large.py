"""musicgen-large [audio] -- 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048,
decoder-only over EnCodec tokens [arXiv:2306.05284; hf].  Backbone only: the
EnCodec frontend is a stub; input_specs provides precomputed frame embeddings
(B, S, d_model) per the assignment."""
from repro.configs.base import dense, spec
from repro.models.api import LMConfig

SPEC = spec(
    "musicgen-large",
    LMConfig(name="musicgen-large", d_model=2048, n_heads=32, n_kv_heads=32,
             d_ff=8192, vocab=2048, n_layers=48, pattern=(dense(),),
             frontend="audio_stub"),
    LMConfig(name="musicgen-smoke", d_model=64, n_heads=4, n_kv_heads=4,
             d_ff=128, vocab=64, n_layers=4, pattern=(dense(),),
             frontend="audio_stub"),
    family="audio")
