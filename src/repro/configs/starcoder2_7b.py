"""starcoder2-7b [dense] -- 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152, GQA + RoPE [arXiv:2402.19173; hf]."""
from repro.configs.base import dense, spec
from repro.models.api import LMConfig

SPEC = spec(
    "starcoder2-7b",
    LMConfig(name="starcoder2-7b", d_model=4608, n_heads=36, n_kv_heads=4,
             d_ff=18432, vocab=49152, n_layers=32, pattern=(dense(),)),
    LMConfig(name="starcoder2-smoke", d_model=64, n_heads=4, n_kv_heads=2,
             d_ff=192, vocab=256, n_layers=4, pattern=(dense(),)),
    family="dense")
