"""Shared helpers for per-architecture config modules."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.api import BlockDef


def dense(kind: str = "attn", moe: bool = False, ffn: bool = True) -> BlockDef:
    return BlockDef(kind=kind, use_moe=moe, has_ffn=ffn)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """One assigned architecture: production config + reduced smoke config."""
    arch_id: str
    config: "LMConfig"               # full production dims (dry-run only)
    smoke: "LMConfig"                # tiny same-family config (CPU tests)
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    skip_shapes: Tuple[str, ...] = ()
    skip_reason: Optional[str] = None


def spec(arch_id, config, smoke, family, skip_long=True) -> ArchSpec:
    """skip_long=True marks pure full-attention archs: long_500k decode would
    need a full 500k KV cache in every layer (no sub-quadratic path)."""
    skips = ("long_500k",) if skip_long else ()
    reason = ("pure full-attention architecture: 500k decode state is a "
              "full KV cache in every layer (no sub-quadratic path)"
              if skip_long else None)
    return ArchSpec(arch_id, config, smoke, family, skips, reason)
