"""granite-moe-3b-a800m [moe] -- 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert
vocab=49155, MoE 40e top-8 [hf:ibm-granite/granite-3.0-3b-a800m-base]."""
from repro.configs.base import dense, spec
from repro.models.api import LMConfig, MoECfg

SPEC = spec(
    "granite-moe-3b-a800m",
    LMConfig(name="granite-moe-3b-a800m", d_model=1536, n_heads=24,
             n_kv_heads=8, d_ff=512, vocab=49155, n_layers=32,
             pattern=(dense(moe=True),),
             moe=MoECfg(n_experts=40, top_k=8, d_ff=512)),
    LMConfig(name="granite-smoke", d_model=48, n_heads=3, n_kv_heads=1,
             d_ff=32, vocab=256, n_layers=3, pattern=(dense(moe=True),),
             moe=MoECfg(n_experts=8, top_k=4, d_ff=32, capacity_factor=0.0)),
    family="moe")
