"""gemma2-2b [dense/hybrid-attn] -- 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000, local+global alternating attention (window 4096), attn/logit
softcaps [arXiv:2408.00118; hf].  head_dim=256 (q width 2048 != d_model)."""
from repro.configs.base import dense, spec
from repro.models.api import LMConfig

SPEC = spec(
    "gemma2-2b",
    LMConfig(name="gemma2-2b", d_model=2304, n_heads=8, n_kv_heads=4,
             d_ff=9216, vocab=256000, n_layers=26, head_dim=256,
             pattern=(dense("local_attn"), dense("attn")),
             window=4096, attn_softcap=50.0, logit_softcap=30.0),
    LMConfig(name="gemma2-smoke", d_model=64, n_heads=4, n_kv_heads=2,
             d_ff=128, vocab=256, n_layers=4, head_dim=16,
             pattern=(dense("local_attn"), dense("attn")),
             window=8, attn_softcap=50.0, logit_softcap=30.0),
    family="hybrid-attn", skip_long=False)
