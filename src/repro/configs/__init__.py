"""Architecture configs: one module per assigned architecture.

``get(arch_id)`` returns an :class:`ArchSpec` with the full production config,
a reduced smoke config of the same family, and shape applicability.
"""
from repro.configs.registry import ARCHS, ArchSpec, get

__all__ = ["ARCHS", "ArchSpec", "get"]
