"""llama4-scout-17b-a16e [moe] -- 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1 [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.configs.base import dense, spec
from repro.models.api import LMConfig, MoECfg

SPEC = spec(
    "llama4-scout-17b-a16e",
    LMConfig(name="llama4-scout-17b-a16e", d_model=5120, n_heads=40,
             n_kv_heads=8, d_ff=8192, vocab=202048, n_layers=48,
             pattern=(dense(moe=True),),
             moe=MoECfg(n_experts=16, top_k=1, d_ff=8192)),
    LMConfig(name="llama4-smoke", d_model=64, n_heads=4, n_kv_heads=2,
             d_ff=64, vocab=256, n_layers=4, pattern=(dense(moe=True),),
             moe=MoECfg(n_experts=4, top_k=1, d_ff=64, capacity_factor=0.0)),
    family="moe")
