"""phi4-mini-3.8b [dense] -- 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064, RoPE SwiGLU GQA [arXiv:2412.08905; hf]."""
from repro.configs.base import dense, spec
from repro.models.api import LMConfig

SPEC = spec(
    "phi4-mini-3.8b",
    LMConfig(name="phi4-mini-3.8b", d_model=3072, n_heads=24, n_kv_heads=8,
             d_ff=8192, vocab=200064, n_layers=32, pattern=(dense(),)),
    LMConfig(name="phi4-smoke", d_model=48, n_heads=3, n_kv_heads=1, d_ff=96,
             vocab=256, n_layers=3, pattern=(dense(),)),
    family="dense")
