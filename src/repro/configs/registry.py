"""Registry of assigned architectures -- collects the per-arch modules.

``long_500k`` runs only for sub-quadratic archs (SSM / hybrid / local+global);
see DESIGN.md "long_500k shape skips".
"""
from __future__ import annotations

from repro.configs.base import ArchSpec
from repro.configs import (jamba_1_5_large_398b, internlm2_20b,
                           phi4_mini_3_8b, starcoder2_7b, gemma2_2b,
                           musicgen_large, granite_moe_3b_a800m,
                           llama4_scout_17b_a16e, llama_3_2_vision_90b,
                           mamba2_780m)

_MODULES = (jamba_1_5_large_398b, internlm2_20b, phi4_mini_3_8b,
            starcoder2_7b, gemma2_2b, musicgen_large, granite_moe_3b_a800m,
            llama4_scout_17b_a16e, llama_3_2_vision_90b, mamba2_780m)

ARCHS = {m.SPEC.arch_id: m.SPEC for m in _MODULES}


def get(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]
