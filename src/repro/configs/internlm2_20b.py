"""internlm2-20b [dense] -- 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 [arXiv:2403.17297; hf]."""
from repro.configs.base import dense, spec
from repro.models.api import LMConfig

SPEC = spec(
    "internlm2-20b",
    LMConfig(name="internlm2-20b", d_model=6144, n_heads=48, n_kv_heads=8,
             d_ff=16384, vocab=92544, n_layers=48, pattern=(dense(),)),
    LMConfig(name="internlm2-smoke", d_model=64, n_heads=4, n_kv_heads=2,
             d_ff=128, vocab=256, n_layers=4, pattern=(dense(),)),
    family="dense")
