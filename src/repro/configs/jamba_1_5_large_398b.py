"""jamba-1.5-large-398b [hybrid] -- 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba+attn 1:7 interleave, MoE every 2nd layer
[arXiv:2403.19887; hf].  Pattern period 8: attention at position 4, MoE on odd
positions (36 MoE layers -> ~398B total / ~94B active)."""
from repro.configs.base import ArchSpec, dense, spec
from repro.models.api import BlockDef, LMConfig, MoECfg, SSMCfg

PATTERN = tuple(
    BlockDef(kind=("attn" if i == 4 else "mamba"), use_moe=(i % 2 == 1))
    for i in range(8))

SPEC = spec(
    "jamba-1.5-large-398b",
    LMConfig(
        name="jamba-1.5-large-398b", d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=24576, vocab=65536, n_layers=72, pattern=PATTERN,
        moe=MoECfg(n_experts=16, top_k=2, d_ff=24576),
        ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256)),
    LMConfig(
        name="jamba-smoke", d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, n_layers=8, pattern=PATTERN,
        moe=MoECfg(n_experts=4, top_k=2, d_ff=128, capacity_factor=0.0),
        ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=8)),
    family="hybrid", skip_long=False)
