"""mamba2-780m [ssm] -- 48L d_model=1536 attn-free d_ff=0 vocab=50280,
ssm_state=128, SSD (state-space duality) [arXiv:2405.21060; unverified].
Blocks carry no separate FFN (mixing lives in the SSD block)."""
from repro.configs.base import spec
from repro.models.api import BlockDef, LMConfig, SSMCfg

SPEC = spec(
    "mamba2-780m",
    LMConfig(name="mamba2-780m", d_model=1536, n_heads=1, n_kv_heads=1,
             d_ff=0, vocab=50280, n_layers=48,
             pattern=(BlockDef(kind="mamba", has_ffn=False),),
             ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64,
                        chunk=256)),
    LMConfig(name="mamba2-smoke", d_model=64, n_heads=1, n_kv_heads=1,
             d_ff=0, vocab=256, n_layers=4,
             pattern=(BlockDef(kind="mamba", has_ffn=False),),
             ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=16,
                        chunk=8)),
    family="ssm", skip_long=False)
