"""llama-3.2-vision-90b [vlm] -- 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, cross-attn image layers every 5th [hf:meta-llama/
Llama-3.2-11B-Vision; unverified].  Backbone only: vision frontend is a stub;
input_specs provides precomputed patch embeddings (B, 1600, d_model)."""
from repro.configs.base import dense, spec
from repro.models.api import LMConfig

SPEC = spec(
    "llama-3.2-vision-90b",
    LMConfig(name="llama-3.2-vision-90b", d_model=8192, n_heads=64,
             n_kv_heads=8, d_ff=28672, vocab=128256, n_layers=100,
             pattern=(dense(), dense(), dense(), dense(),
                      dense("cross_attn")),
             n_img_tokens=1600, frontend="vision_stub"),
    LMConfig(name="llama32v-smoke", d_model=64, n_heads=4, n_kv_heads=2,
             d_ff=128, vocab=256, n_layers=5,
             pattern=(dense(), dense(), dense(), dense(),
                      dense("cross_attn")),
             n_img_tokens=16, frontend="vision_stub"),
    family="vlm")
