# Tier-1 gate (`make test`): fast pre-commit suite, excludes @slow
# end-to-end tests and is bounded at 10 minutes.  `make test-all` runs
# everything (ROADMAP's tier-1 verify command runs the full suite too).
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-all bench-packed bench-cb bench-attn bench-open-loop \
	docs-check

test:
	timeout 600 $(PY) -m pytest -x -q -m "not slow"

test-all:
	$(PY) -m pytest -x -q

bench-packed:
	$(PY) benchmarks/packed_vs_int8.py

bench-cb:
	$(PY) benchmarks/continuous_batching.py

bench-attn:
	$(PY) benchmarks/attention.py

# Poisson open-loop serving (parameters from benchmarks/manifest.json)
bench-open-loop:
	$(PY) benchmarks/open_loop.py --experiment open_loop_sweep

# every docs/ page must be reachable from docs/index.md (CI runs this too)
docs-check:
	$(PY) scripts/check_docs.py
