"""Assemble EXPERIMENTS.md from results/ artifacts.

    PYTHONPATH=src python -m benchmarks.make_experiments_md

Sections: §Repro-T2/T3/T4, §Repro-F7/F8, §Repro-LM, §Dry-run, §Roofline,
§Perf (hillclimb logs are curated inline here; measurements pulled from
results/perf/*.json).
"""
from __future__ import annotations

import json
import pathlib

R = pathlib.Path("results")
PEAK, HBM, LINK = 197e12, 819e9, 50e9


def _load(p):
    f = R / p
    return json.loads(f.read_text()) if f.exists() else None


def terms(r):
    hs, c = r["hlo_stats"], r["collectives"]
    return (hs["flops_per_device"] / PEAK,
            hs["bytes_traffic_per_device"] / HBM,
            c["per_chip_bytes"] / LINK)


def sec_repro():
    out = ["## §Repro — faithful reproduction (synthetic substrate)",
           "",
           "Offline substrate: CIFAR-class synthetic images (DESIGN.md §3); "
           "the paper's *relative* claims are the validation target. "
           "F=full precision, N=uniform 5-bit, L=layer-level flat DDPG "
           "(HAQ-like), C=kernel-wise hierarchical DRL (AutoQ). "
           "rc=resource-constrained (Algorithm 1, target 5 bits), "
           "ag=accuracy-guaranteed. 250 episodes/search; top1_ft = after "
           "QAT fine-tuning (60 steps)."]
    for name, title in (("table2_quant", "§Repro-T2 — network quantization "
                         "(paper Table 2)"),
                        ("table3_binarize", "§Repro-T3 — network "
                         "binarization (paper Table 3)")):
        d = _load(f"repro/{name}.json")
        if not d:
            continue
        out += ["", f"### {title}", "",
                "| scheme | proto | top-1 % | top-1 ft % | act bits | "
                "wei bits | logic ratio |",
                "|---|---|---|---|---|---|---|"]
        for r in d["rows"]:
            ft = r.get("top1_ft")
            out.append(
                f"| {r['scheme']} | {r['protocol']} | {r['top1']:.2f} | "
                f"{ft if ft is None else f'{ft:.2f}'} | "
                f"{r['act_bits']:.2f} | {r['wei_bits']:.2f} | "
                f"{r['logic_ratio']:.4f} |")
    d = _load("repro/table4_compare.json")
    if d:
        a, h = d["autoq_channel"], d["haq_like_layer"]
        out += ["", "### §Repro-T4 — cost at iso-accuracy vs layer-level "
                "DDPG (paper Table 4)", "",
                "| scheme | Δtop-1 (pp) | norm. logic |", "|---|---|---|",
                f"| AutoQ kernel-wise (C/ag) | {a['d_top1']:+.2f} | "
                f"{a['norm_logic']:.4f} |",
                f"| HAQ-like layer-level (L/ag) | {h['d_top1']:+.2f} | "
                f"{h['norm_logic']:.4f} |"]
    d = _load("repro/fig8_convergence.json")
    if d:
        hi, fl = d["hierarchical"], d["flat_ddpg"]

        def milestones(curve):
            best = 0.0
            ms = []
            for i, a in enumerate(curve):
                best = max(best, a)
                if i in (24, 49, 99, 149, len(curve) - 1):
                    ms.append(f"ep{i+1}:{best:.0f}%")
            return " ".join(ms)
        out += ["", "### §Repro-F8 — hierarchical vs flat DDPG convergence "
                "(paper Fig. 8)", "",
                f"- hierarchical best-so-far acc: {milestones(hi['acc_curve'])}"
                f" (best {hi['best_acc']:.1f}%)",
                f"- flat channel DDPG:            {milestones(fl['acc_curve'])}"
                f" (best {fl['best_acc']:.1f}%)"]
    d = _load("repro/fig7_flop_reward.json")
    if d:
        out += ["", "### §Repro-F7 — NetScore vs FLOP-based reward "
                "(paper §4.3 / Fig. 7)", "",
                "| reward | fc-layer weight bits | acc % | logic ratio |",
                "|---|---|---|---|"]
        for k in ("netscore", "flop"):
            r = d[k]
            out.append(f"| {k} | {r['fc_wbits']:.2f} | {r['acc']:.1f} | "
                       f"{r['logic_ratio']:.4f} |")
        gap = d["flop"]["fc_wbits"] - d["netscore"]["fc_wbits"]
        if gap > 0.5:
            out += ["", "The FLOP reward keeps the FC layer's weights fat "
                    "(no logic incentive there), reproducing the paper's "
                    "section 4.3 observation."]
        else:
            out += ["", "Caveat: the paper's section 4.3 effect (FLOP "
                    "reward keeps FC weights fat) did **not** manifest "
                    f"(gap {gap:+.1f} bits) -- our substrate CNN's FC layer "
                    "is only ~330 weights, too small for the weight-count "
                    "term to bite; the paper's ResNet-18 FC has 512k. "
                    "Reported as-is."]
    rows = []
    for f in ("lm_phi4", "lm_mamba2"):
        d = _load(f"repro/{f}.json")
        if d:
            rows.append(d)
    if rows:
        out += ["", "### §Repro-LM — kernel-wise search on assigned-family "
                "LMs (beyond paper)", "",
                "| arch (smoke) | full acc % | uniform-5b acc % | searched "
                "acc % | avg w bits | avg a bits |", "|---|---|---|---|---|---|"]
        for d in rows:
            out.append(f"| {d['arch']} | {d['full_acc']:.1f} | "
                       f"{d['uniform5_acc']:.1f} | {d['searched_acc']:.1f} | "
                       f"{d['avg_wbits']:.2f} | {d['avg_abits']:.2f} |")
    return out


def sec_dryrun():
    rows = []
    for f in sorted((R / "dryrun").glob("*.json")):
        rows.append(json.loads(f.read_text()))
    ok = [r for r in rows if r["status"] == "ok"]
    skip = [r for r in rows if r["status"] == "skip"]
    out = ["", "## §Dry-run — multi-pod lower + compile (deliverable e)", "",
           f"{len(ok)} cells compile OK ({len([r for r in ok if r['mesh']=='single'])} "
           f"single-pod 16x16=256 chips, {len([r for r in ok if r['mesh']=='multi'])} "
           f"multi-pod 2x16x16=512 chips); {len(skip)} documented skips "
           "(long_500k on pure full-attention archs, DESIGN.md §4).", "",
           "| arch | shape | mesh | compile s | HLO GFLOPs/dev | traffic "
           "GB/dev | coll GB/chip | temp GB/dev |", "|---|---|---|---|---|---|---|---|"]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        hs, c = r["hlo_stats"], r["collectives"]
        ma = r.get("memory_analysis", {})
        temp = ma.get("temp_size_in_bytes", 0) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']:.0f} | {hs['flops_per_device']/1e9:.1f} | "
            f"{hs['bytes_traffic_per_device']/1e9:.1f} | "
            f"{c['per_chip_bytes']/1e9:.1f} | {temp:.1f} |")
    out += ["", "Skipped cells:"]
    seen = set()
    for r in skip:
        key = (r["arch"], r["shape"])
        if key in seen:
            continue
        seen.add(key)
        out.append(f"- {r['arch']} x {r['shape']}: {r.get('reason','')}")

    # HBM-fit note: argument bytes (params + opt state + caches) are exact;
    # temp bytes come from the CPU backend and inflate like the traffic
    # numbers (f32 dot upcasts, double-buffered scan carries, unfused
    # attention workspaces).
    args_max = max((r.get("memory_analysis", {})
                    .get("argument_size_in_bytes", 0) for r in ok),
                   default=0) / 1e9
    over = [(r["arch"], r["shape"],
             r["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9)
            for r in ok if r["mesh"] == "single" and
            r.get("memory_analysis", {}).get("temp_size_in_bytes", 0) / 1e9 +
            r.get("memory_analysis", {}).get("argument_size_in_bytes", 0) /
            1e9 > 16.0]
    out += ["",
            f"**HBM fit (v5e, 16 GB/chip)**: resident state (params + "
            f"8-bit Adam moments + caches) fits everywhere -- max argument "
            f"bytes {args_max:.1f} GB/device (jamba-398B train; the int8 "
            f"optimizer-state win).  {len(over)} cells report CPU-backend "
            "temp sizes above 16 GB; these are upper bounds inflated by "
            "the same CPU artifacts corrected in the traffic analysis "
            "(f32 dot upcasts ~2x, double-buffered scan carries, unfused "
            "attention workspaces that live in VMEM on TPU).  The "
            "remat-over-repeats policy bounds true activation residency to "
            "one pattern period; closing the remaining gap on TPU is the "
            "flash-attention/dispatch Pallas work noted in DESIGN.md "
            "section 6b."]
    return out


def sec_roofline():
    rl = _load("roofline.json")
    if not rl:
        return []
    out = ["", "## §Roofline — three-term analysis per (arch x shape), "
           "single-pod 256 chips (deliverable g)", "",
           "Terms (seconds/step): compute = HLO_FLOPs/dev / 197 TFLOP/s; "
           "memory = HBM traffic/dev / 819 GB/s (fusion-granularity "
           "reads+writes, in-place DUS, dequant chains charged at source "
           "dtype); collective = ring-model link bytes / 50 GB/s. "
           "HLO numbers are loop-corrected (launch/hlo.py) -- jax's "
           "cost_analysis undercounts scan bodies by the trip count. "
           "useful = MODEL_FLOPS / HLO_FLOPs_global, MODEL_FLOPS = "
           "6·N_active·D (train) or 2·N_active·D (prefill/decode).", "",
           "| arch | shape | compute s | memory s | collective s | dominant "
           "| useful | what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|"]
    for c in rl:
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['t_compute_s']:.2e} | "
            f"{c['t_memory_s']:.2e} | {c['t_collective_s']:.2e} | "
            f"**{c['dominant']}** | {c['useful_ratio']:.2f} | "
            f"{c['advice']} |")
    doms = {}
    for c in rl:
        doms[c["dominant"]] = doms.get(c["dominant"], 0) + 1
    out += ["", f"Bottleneck census: {doms}.  Decode/prefill are "
            "memory-bound -- exactly the term AutoQ's kernel-wise "
            "bit-width policies shrink; train is collective-bound at this "
            "mesh (FSDP gathers + TP reductions)."]
    return out


def _fmt_terms(r):
    if r is None or r.get("status") != "ok":
        return "(cell unavailable)"
    tc, tm, tl = terms(r)
    dom = max((("compute", tc), ("memory", tm), ("collective", tl)),
              key=lambda kv: kv[1])
    return (f"compute {tc:.3g}s / memory {tm:.3g}s / collective {tl:.3g}s "
            f"(dominant: {dom[0]})")


def _perf_section():
    perf = pathlib.Path("EXPERIMENTS_PERF.md")
    if not perf.exists():
        return []
    txt = perf.read_text()
    subs = {
        "PAIR_C_BASE": "dryrun/internlm2-20b__decode_32k__single.json",
        "PAIR_C_H1": "perf/internlm2-20b__decode_32k__single__quant_serve.json",
        "PAIR_C_H2": "perf/internlm2-20b__decode_32k__single__kv8+quant_serve.json",
        "PAIR_C_H3": "perf/internlm2-20b__decode_32k__single__kv8.json",
        "PAIR_A_BASE": "dryrun/jamba-1.5-large-398b__train_4k__single.json",
        "PAIR_A_H3M": "perf/jamba-1.5-large-398b__train_4k__single__"
                      "logits_sharded+remat_dots.json",
        "PAIR_B_BASE": "dryrun/granite-moe-3b-a800m__train_4k__single.json",
        "PAIR_B_H1": "perf/granite-moe-3b-a800m__train_4k__single__ep_pad.json",
        "PAIR_B_H2": "perf/granite-moe-3b-a800m__train_4k__single__moe_local.json",
        "PAIR_B_H3": "perf/granite-moe-3b-a800m__train_4k__single__remat_dots.json",
    }
    # multi-pod baseline for the compress_pod comparison
    mb = _load("dryrun/jamba-1.5-large-398b__train_4k__multi.json")
    if mb:
        txt = txt.replace("PAIR_A_MULTI_BASE", _fmt_terms(mb))
    for token, path in subs.items():
        txt = txt.replace(token, _fmt_terms(_load(path)))
    return ["", txt]


def main():
    parts = ["# EXPERIMENTS", "",
             "All numbers produced by code in this repo; regenerate with "
             "`python -m benchmarks.make_experiments_md`.  Hardware "
             "constants: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM, "
             "~50 GB/s/link ICI; 256 chips/pod."]
    parts += sec_repro()
    parts += sec_dryrun()
    parts += sec_roofline()
    parts += _perf_section()
    pathlib.Path("EXPERIMENTS.md").write_text("\n".join(parts) + "\n")
    print(f"wrote EXPERIMENTS.md ({len(parts)} blocks)")


if __name__ == "__main__":
    main()
