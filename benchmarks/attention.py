"""Attention subsystem benchmark: Pallas kernels vs the jnp oracle.

Three measurements, each with a hard numerical-parity gate (the assert is
the point -- CI runs ``--smoke`` so kernel parity is checked on every PR):

* **prefill** -- tiled flash kernel vs the chunked-flash jnp reference on a
  causal (optionally windowed) prompt;
* **paged decode** -- the block-table-walking kernel vs the dense-gather
  path on a ragged page pool (mixed in-flight lengths, idle lanes);
* **engine tok/s** -- ``ServeEngine.run`` over 8 interleaved requests on
  ``attn_impl="pallas"`` vs ``attn_impl="ref"``, token streams compared.

Timing caveat: off-TPU the kernels execute in Pallas *interpret* mode --
correct but emulated, so wall-clock comparisons against the jnp oracle are
meaningless and the "paged decode no slower than the dense gather" check
only arms on a real TPU backend, where the kernel's HBM story (stream pages
into VMEM, skip out-of-window pages, no (B, nb*page_size) gather buffer)
is what the measurement reflects.

Usage:  PYTHONPATH=src python benchmarks/attention.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.kernels.attention import INTERPRET
from repro.models import LM
from repro.models.layers import attention, paged_attention
from repro.models.transformer import POS_SENTINEL
from repro.serve import ServeEngine

TOL = dict(rtol=2e-4, atol=2e-5)


def _timeit(fn, *args, reps=3):
    fn(*args)                                   # compile / warm
    t0 = time.time()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return out, (time.time() - t0) / reps


def bench_prefill(S, Hkv, G, D, window):
    rng = np.random.default_rng(0)
    B = 2
    q = jnp.asarray(rng.normal(size=(B, S, Hkv * G, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def run(impl):      # jit over operands (constants would fold at compile)
        return jax.jit(lambda a, b, c, p: attention(
            a, b, c, q_pos=p, kv_pos=p, window=window, impl=impl))

    ref, t_ref = _timeit(run("ref"), q, k, v, pos)
    got, t_pal = _timeit(run("pallas"), q, k, v, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)
    print(f"prefill  S={S:5d} window={window}: ref {t_ref*1e3:8.2f} ms | "
          f"flash kernel {t_pal*1e3:8.2f} ms | parity OK")
    return t_ref, t_pal


def bench_paged_decode(lens, ps, Hkv, G, D, window):
    rng = np.random.default_rng(1)
    B = len(lens)
    nb = -(-max(lens) // ps) + 1
    P = 1 + sum(-(-s // ps) for s in lens)
    k = jnp.asarray(rng.normal(size=(P, ps, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(P, ps, Hkv, D)), jnp.float32)
    pos = np.full((P, ps), POS_SENTINEL, np.int32)
    bt = np.zeros((B, nb), np.int32)
    nxt = 1
    for i, s in enumerate(lens):
        n = -(-s // ps)
        bt[i, :n] = range(nxt, nxt + n)
        for p in range(s):
            pos[bt[i, p // ps], p % ps] = p
        nxt += n
    pos, bt = jnp.asarray(pos), jnp.asarray(bt)
    q = jnp.asarray(rng.normal(size=(B, 1, Hkv * G, D)), jnp.float32)
    q_pos = jnp.asarray([[s - 1] for s in lens], jnp.int32)

    def run(impl):
        return jax.jit(lambda a, b, c, p, t, qp: paged_attention(
            a, b, c, p, t, q_pos=qp, window=window, impl=impl))

    ref, t_ref = _timeit(run("ref"), q, k, v, pos, bt, q_pos)
    got, t_pal = _timeit(run("pallas"), q, k, v, pos, bt, q_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)
    print(f"paged decode  B={B} pages<= {nb}: dense gather {t_ref*1e3:8.2f} "
          f"ms | page-walk kernel {t_pal*1e3:8.2f} ms | parity OK")
    return t_ref, t_pal


def bench_engine(n_new, max_len):
    cfg = ARCHS["internlm2-20b"].smoke
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    lens = np.linspace(4, max_len - n_new, 8).astype(int)
    reqs = [(rng.integers(0, cfg.vocab, size=int(s)).astype(np.int32), n_new)
            for s in lens]

    def toks_per_s(impl):
        eng = ServeEngine(model, params, max_len=max_len, attn_impl=impl)
        eng.run(reqs[:1], page_size=4, max_slots=8)          # warm jit
        res = eng.run(reqs, page_size=4, max_slots=8)
        return res["outputs"], res["stats"].decode_tok_per_s

    out_r, tps_r = toks_per_s("ref")
    out_p, tps_p = toks_per_s("pallas")
    for i, (a, b) in enumerate(zip(out_p, out_r)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")
    print(f"engine  8 interleaved x {n_new} new: ref {tps_r:8.1f} tok/s | "
          f"pallas {tps_p:8.1f} tok/s | streams identical")
    return tps_r, tps_p


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized shapes (parity gate, minimal wall-clock)")
    args = ap.parse_args()
    if args.smoke:
        bench_prefill(64, 2, 2, 16, window=None)
        t_ref, t_pal = bench_paged_decode([37, 9, 22, 5], 8, 2, 2, 16,
                                          window=16)
        tps_r, tps_p = bench_engine(n_new=8, max_len=24)
    else:
        bench_prefill(512, 2, 2, 64, window=None)
        bench_prefill(512, 2, 2, 64, window=128)
        t_ref, t_pal = bench_paged_decode(
            [390, 51, 222, 117, 303, 64, 480, 12], 16, 2, 2, 64, window=128)
        tps_r, tps_p = bench_engine(n_new=32, max_len=128)
    if INTERPRET:
        print("NOTE: off-TPU run -- kernels in interpret mode; timings are "
              "emulation, only the parity gates are meaningful here.")
    else:
        # acceptance: paged decode must not lose to the dense-gather path
        assert t_pal <= t_ref * 1.05, (t_pal, t_ref)
        assert tps_p >= tps_r * 0.95, (tps_p, tps_r)
        print("TPU perf gate: page-walk decode >= dense-gather path OK")


if __name__ == "__main__":
    main()
