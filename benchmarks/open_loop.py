"""Open-loop serving benchmark: Poisson arrivals through ``serve()``.

The closed-loop benches (continuous_batching.py) measure the engine with
every request present at t=0 -- the batch regime.  This bench measures
the *serving* regime the open-loop split exists for: requests arrive by
a Poisson process while the step loop runs, so queueing, admission and
the pipelined dispatch all matter.

Three sections:

* **closed-loop baselines** -- the same workload all-at-once through
  ``run(overlap=False)`` (synchronous stepping: the pre-split loop's
  schedule) and ``run(overlap=True)`` (pipelined dispatch), recording
  both decode rates side by side.  Gate: the overlapped rate holds the
  synchronous rate (x ``overlap_floor``, slack for CI timing noise --
  both paths sample on device, the pipeline saves the per-step blocking
  token sync).
* **offered-load sweep** -- arrival rates derived from the *measured*
  closed-loop capacity (``load_factor`` x capacity in requests/s),
  inter-arrival gaps drawn i.i.d. exponential.  Per load: goodput
  (completed tokens / makespan), queue-wait P50/P99, TTFT P50/P99
  (arrival-relative), e2e P99, inter-token-latency P99, sheds.
* **SLO mode** (``queue_slo_factor``) -- the same sweep with a
  queue-wait deadline (factor x the per-request ideal service time):
  overload sheds queued requests instead of serving dead-on-arrival
  first tokens; survivors keep parity.

Gates (asserted):

* every non-shed stream at every offered load is bit-identical to its
  independent serial ``generate`` oracle -- arrival pattern is invisible
  to the numerics;
* overlapped closed-loop decode tok/s >= ``overlap_floor`` x the
  synchronous closed-loop rate (both always printed);
* jit variants stay bounded across *all* runs together: <= 2
  ``model_step`` shapes, <= 2 ``sample_step`` shapes, batch-1 prefill
  never traced -- open-loop arrival patterns compile nothing new.

Parameters come from benchmarks/manifest.json (``--experiment NAME``;
``--smoke`` is shorthand for ``--experiment open_loop_smoke``), so
sweeps are versioned data; CLI flags override.  Timing uses the jnp
``ref`` attention backend by default, as in continuous_batching.py
(off-TPU the Pallas kernels run in interpret mode, whose overhead would
distort the engine-level comparison).

Usage:  PYTHONPATH=src python benchmarks/open_loop.py
            [--smoke | --experiment NAME] [--requests N] [--n-new N]
            [--load-factors F ...] [--attn-impl ref|pallas] [--seed S]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import LM
from repro.serve import FrontEnd, ServeEngine

MANIFEST = pathlib.Path(__file__).parent / "manifest.json"


def _manifest_params(name: str) -> dict:
    entries = json.loads(MANIFEST.read_text())["experiments"]
    by_name = {e["name"]: e for e in entries}
    if name not in by_name:
        raise SystemExit(f"unknown experiment {name!r}; manifest has "
                         f"{sorted(by_name)}")
    return dict(by_name[name].get("params", {}))


def _workload(n_requests: int, n_new: int, vocab: int, max_len: int,
              seed: int = 0):
    """Mixed prompt lengths (distinct, page-ragged), fixed decode length."""
    rng = np.random.default_rng(seed)
    cap = max_len - n_new
    lens = [1 + (3 + 5 * i) % cap for i in range(n_requests)]
    return [(rng.integers(0, vocab, size=int(s)).astype(np.int32), n_new)
            for s in lens]


def _pct(d: dict, q: int) -> float:
    return d.get(q, float("nan"))


def _fmt_ms(x: float) -> str:
    return f"{x * 1e3:7.1f}ms"


def _open_loop_run(eng, reqs, offsets, *, page_size, max_slots,
                   queue_slo_s=None):
    """One serve() drain: submit the trace with absolute arrival times,
    measure makespan from the first arrival to the loop returning."""
    fe = FrontEnd(queue_slo_s=queue_slo_s)
    t0 = fe.now() + 0.005            # first arrival strictly in the future
    rids = [fe.submit(r, at=t0 + off).rid
            for r, off in zip(reqs, offsets)]
    res = eng.serve(fe, page_size=page_size, max_slots=max_slots)
    makespan = fe.now() - t0
    return rids, res, makespan


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--experiment", default=None,
                    help="manifest.json entry to load parameters from")
    ap.add_argument("--smoke", action="store_true",
                    help="shorthand for --experiment open_loop_smoke (CI)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--n-new", type=int, default=None)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--page-size", type=int, default=None)
    ap.add_argument("--max-slots", type=int, default=None)
    ap.add_argument("--load-factors", type=float, nargs="*", default=None,
                    help="offered load as a multiple of measured capacity")
    ap.add_argument("--overlap-floor", type=float, default=None,
                    help="gate: overlapped decode tok/s >= floor * sync "
                         "(smoke defaults < 1.0: CI timing slack)")
    ap.add_argument("--queue-slo-factor", type=float, default=None,
                    help="queue SLO as a multiple of the ideal per-request "
                         "service time (default: no shedding)")
    ap.add_argument("--attn-impl", choices=("ref", "pallas"), default="ref")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    exp = args.experiment or ("open_loop_smoke" if args.smoke else None)
    params = _manifest_params(exp) if exp else {}
    defaults = {"requests": 8, "n_new": 8, "d_model": 64, "max_len": 48,
                "page_size": 4, "max_slots": 4, "load_factors": [0.5, 1.5],
                "overlap_floor": 0.8, "queue_slo_factor": None}
    for key, fallback in defaults.items():
        if getattr(args, key) is None:
            setattr(args, key, params.get(key, fallback))

    cfg = dataclasses.replace(ARCHS["internlm2-20b"].smoke,
                              d_model=args.d_model, d_ff=4 * args.d_model)
    model = LM(cfg)
    model_params = model.init(jax.random.PRNGKey(0))
    reqs = _workload(args.requests, args.n_new, cfg.vocab, args.max_len,
                     seed=args.seed)
    print(f"workload: {args.requests} requests, prompts "
          f"{[int(t.size) for t, _ in reqs]}, {args.n_new} new tokens "
          f"each, d_model={cfg.d_model}, page_size={args.page_size}, "
          f"max_slots={args.max_slots}")

    # one engine throughout: the jit-variant gate then covers every run at
    # once (closed-loop, every load, both overlap settings share variants)
    eng = ServeEngine(model, model_params, max_len=args.max_len,
                      attn_impl=args.attn_impl)
    # warm both entry points so wall-clock sections measure compiled code
    eng.generate(reqs[0][0][None], 2)
    eng.run(reqs[:1], page_size=args.page_size, max_slots=args.max_slots)

    # ---- serial oracle (parity reference + ideal service time) ----------
    refs, ser_decode_s, ser_toks = [], 0.0, 0
    for toks, n_new in reqs:
        out = eng.generate(toks[None], n_new)
        refs.append(out["tokens"][0])
        ser_decode_s += out["stats"].decode_s
        ser_toks += out["stats"].tokens_out

    # the serial oracle traced generate's own prefill/decode jits (one per
    # distinct prompt length -- the explosion serving must never share);
    # every serving section below must add *no* traces beyond model_step +
    # sample_step
    oracle_counts = dict(eng.trace_counts)

    # ---- closed-loop baselines: sync (pre-split schedule) vs overlapped -
    base = {}
    for label, overlap in (("sync", False), ("overlapped", True)):
        t0 = time.monotonic()
        res = eng.run(reqs, page_size=args.page_size,
                      max_slots=args.max_slots, overlap=overlap)
        wall = time.monotonic() - t0
        st = res["stats"]
        agg = st.tokens_out / wall if wall else 0.0
        base[label] = (st, agg)
        print(f"closed {label:10s}: decode {st.decode_tok_per_s:8.1f} "
              f"tok/s, aggregate {agg:8.1f} tok/s ({st.steps} steps, "
              f"overlapped={st.overlapped})")
        for i, (ref, got) in enumerate(zip(refs, res["outputs"])):
            np.testing.assert_array_equal(
                got, ref, err_msg=f"closed-loop {label}: request {i} "
                                  "diverged from generate")
    sync_rate = base["sync"][0].decode_tok_per_s
    ovl_rate = base["overlapped"][0].decode_tok_per_s
    # capacity for the offered-load sweep: the sustained closed-loop rate
    cap_req_s = base["overlapped"][1] / args.n_new

    # ---- offered-load sweep ---------------------------------------------
    ideal_s = args.n_new / max(sync_rate, 1e-9)     # per-request service
    slo = (args.queue_slo_factor * ideal_s
           if args.queue_slo_factor is not None else None)
    if slo is not None:
        print(f"queue SLO: {slo * 1e3:.1f}ms "
              f"({args.queue_slo_factor}x ideal service time)")
    rng = np.random.default_rng(args.seed + 1)
    sweep = []
    for factor in args.load_factors:
        rate = factor * max(cap_req_s, 1e-9)
        offsets = np.cumsum(rng.exponential(1.0 / rate, len(reqs)))
        rids, res, makespan = _open_loop_run(
            eng, reqs, offsets, page_size=args.page_size,
            max_slots=args.max_slots, queue_slo_s=slo)
        st = res["stats"]
        shed = set(res["shed"])
        good_toks = sum(len(res["outputs"][rid]) for rid in rids
                        if rid not in shed)
        goodput = good_toks / makespan if makespan else 0.0
        qw, tt = st.queue_wait_percentiles(), st.ttft_percentiles()
        e2, it = st.e2e_percentiles(), st.itl_percentiles()
        sweep.append((factor, goodput, st))
        print(f"load {factor:4.2f}x ({rate:6.2f} req/s): goodput "
              f"{goodput:8.1f} tok/s, queue-wait P50/P99 "
              f"{_fmt_ms(_pct(qw, 50))}/{_fmt_ms(_pct(qw, 99))}, TTFT "
              f"P50/P99 {_fmt_ms(_pct(tt, 50))}/{_fmt_ms(_pct(tt, 99))}, "
              f"e2e P99 {_fmt_ms(_pct(e2, 99))}, ITL P99 "
              f"{_fmt_ms(_pct(it, 99))}, shed {st.n_shed}/{len(reqs)}")
        # parity: arrival pattern is invisible to the numerics
        for i, rid in enumerate(rids):
            if rid in shed:
                assert res["outputs"][rid].size == 0
                continue
            np.testing.assert_array_equal(
                res["outputs"][rid], refs[i],
                err_msg=f"load {factor}x: request {i} diverged from the "
                        "serial generate oracle")
        assert st.overlapped, "open-loop serving should pipeline by default"

    # ---- gates ----------------------------------------------------------
    counts = dict(eng.trace_counts)
    print(f"jit traces (all sections, one engine): {counts}")
    assert counts["model_step"] <= 2, (
        "open-loop serving must keep the closed-loop variant bound: "
        "mixed-step + pure-decode only", counts)
    assert counts.get("sample_step", 0) <= 2, (
        "the batched device sampler compiles at most two shapes", counts)
    for name in ("prefill", "decode_step", "decode_step_paged"):
        assert counts.get(name, 0) == oracle_counts.get(name, 0), (
            f"serving must never trace {name} (generate-only path)",
            counts, oracle_counts)
    print(f"decode tok/s: overlapped {ovl_rate:.1f} vs sync {sync_rate:.1f} "
          f"({ovl_rate / max(sync_rate, 1e-9):.2f}x, floor "
          f"{args.overlap_floor})")
    assert ovl_rate >= args.overlap_floor * sync_rate, (
        "pipelined dispatch must hold the synchronous decode rate",
        ovl_rate, sync_rate, args.overlap_floor)
    print("OK: open-loop parity + jit-variant + overlap-rate gates passed")


if __name__ == "__main__":
    main()
