"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Fast mode by default (seconds per
bench); the full-scale reproduction runs live in benchmarks/repro_autoq.py
(--full) and are summarized into EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, n=3, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n * 1e6      # us


def _substrate():
    from benchmarks.repro_autoq import train_substrate
    return train_substrate(steps=80)


def bench_table2_quant(model, params, val, full_acc):
    """Table 2: one kernel-wise quantization search episode."""
    from repro.core import (HierarchicalAgent, QuantEnv, RewardCfg,
                            make_cnn_evaluator)
    graph = model.graph()
    ev = make_cnn_evaluator(model, params, graph, val)
    env = QuantEnv(graph, params, ev, RewardCfg.accuracy_guaranteed())
    agent = HierarchicalAgent(env, seed=0, updates_per_episode=4)
    agent.run_episode(noise=0.5)             # compile warmup
    us = _time(lambda: agent.run_episode(noise=0.3), n=3, warmup=0)
    log, _ = agent.run_episode(noise=0.1)
    return us, f"ep_acc={log.acc:.1f}%_avg_wbits={log.avg_wbits:.2f}"


def bench_table3_binarize(model, params, val, full_acc):
    """Table 3: one kernel-wise binarization search episode."""
    from repro.core import (HierarchicalAgent, QuantEnv, RewardCfg,
                            make_cnn_evaluator)
    from repro.quant.policy import QuantMode
    graph = model.graph()
    ev = make_cnn_evaluator(model, params, graph, val,
                            mode=QuantMode.BINARIZE)
    env = QuantEnv(graph, params, ev, RewardCfg.accuracy_guaranteed(),
                   mode=QuantMode.BINARIZE)
    agent = HierarchicalAgent(env, seed=0, updates_per_episode=4)
    agent.run_episode(noise=0.5)
    us = _time(lambda: agent.run_episode(noise=0.3), n=3, warmup=0)
    log, _ = agent.run_episode(noise=0.1)
    return us, f"ep_acc={log.acc:.1f}%_avg_bbn={log.avg_wbits:.2f}"


def bench_table4_compare(model, params, val, full_acc):
    """Table 4: evaluator throughput (the search bottleneck) + stored
    cost-at-iso-accuracy if the full run exists."""
    from repro.core import make_cnn_evaluator
    from repro.quant.policy import QuantPolicy
    graph = model.graph()
    ev = make_cnn_evaluator(model, params, graph, val)
    p = QuantPolicy.uniform(graph, 5.0)
    us = _time(lambda: ev(p), n=10)
    f = pathlib.Path("results/repro/table4_compare.json")
    if f.exists():
        d = json.loads(f.read_text())
        derived = (f"autoq_logic={d['autoq_channel']['norm_logic']:.4f}_"
                   f"haq_logic={d['haq_like_layer']['norm_logic']:.4f}")
    else:
        derived = f"uniform5_acc={ev(p):.1f}%"
    return us, derived


def bench_fig8_convergence(model, params, val, full_acc):
    """Fig 8: hierarchical-vs-flat episode cost at channel granularity."""
    from repro.core import (FlatAgent, HierarchicalAgent, QuantEnv, RewardCfg,
                            make_cnn_evaluator)
    graph = model.graph()
    ev = make_cnn_evaluator(model, params, graph, val)
    env = QuantEnv(graph, params, ev, RewardCfg.accuracy_guaranteed())
    hier = HierarchicalAgent(env, seed=0, updates_per_episode=2)
    flat = FlatAgent(env, seed=0, granularity="channel",
                     updates_per_episode=2)
    hier.run_episode(noise=0.5)
    flat.run_episode(noise=0.5)
    us_h = _time(lambda: hier.run_episode(noise=0.3), n=2, warmup=0)
    us_f = _time(lambda: flat.run_episode(noise=0.3), n=2, warmup=0)
    return us_h, f"flat_episode_us={us_f:.0f}"


def bench_kernel_quant_matmul(*_):
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256, 1024)), jnp.float32)
    qw = jnp.asarray(rng.integers(-127, 128, size=(1024, 1024)), jnp.int8)
    s = jnp.asarray(rng.uniform(0.01, 0.1, size=(1024,)), jnp.float32)
    jitted = jax.jit(lambda a, b, c: ref.quant_matmul_ref(a, b, c))
    jitted(x, qw, s).block_until_ready()
    us = _time(lambda: jitted(x, qw, s).block_until_ready(), n=10)
    y = ops.quant_matmul(x[:128, :128], qw[:128, :128], s[:128])
    yr = ref.quant_matmul_ref(x[:128, :128], qw[:128, :128], s[:128])
    err = float(jnp.max(jnp.abs(y - yr)))
    return us, f"pallas_interpret_maxerr={err:.1e}"


def bench_kernel_binary_matmul(*_):
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    B = jnp.asarray(rng.choice([-1, 1], size=(4, 512, 512)), jnp.int8)
    a = jnp.asarray(rng.uniform(0.1, 1.0, size=(4, 512)), jnp.float32)
    jitted = jax.jit(lambda p, q, r: ref.binary_matmul_ref(p, q, r))
    jitted(x, B, a).block_until_ready()
    us = _time(lambda: jitted(x, B, a).block_until_ready(), n=10)
    y = ops.binary_matmul(x[:128, :128], B[:, :128, :128], a[:, :128])
    err = float(jnp.max(jnp.abs(
        y - ref.binary_matmul_ref(x[:128, :128], B[:, :128, :128],
                                  a[:, :128]))))
    return us, f"pallas_interpret_maxerr={err:.1e}"


def bench_kernel_fake_quant(*_):
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2048, 1024)), jnp.float32)
    bits = jnp.asarray(rng.integers(1, 9, size=(1024,)), jnp.float32)
    lv = jnp.maximum(2.0 ** (bits - 1) - 1, 1.0)
    amax = jnp.max(jnp.abs(x), axis=0)
    sc = jnp.where(amax > 0, amax / lv, 1.0)
    jitted = jax.jit(lambda a, b, c, d: ref.fake_quant_ref(a, b, c, d))
    jitted(x, sc, lv, bits).block_until_ready()
    us = _time(lambda: jitted(x, sc, lv, bits).block_until_ready(), n=10)
    y = ops.fake_quant_channels(x[:256, :128], sc[:128], lv[:128], bits[:128])
    err = float(jnp.max(jnp.abs(
        y - ref.fake_quant_ref(x[:256, :128], sc[:128], lv[:128],
                               bits[:128]))))
    return us, f"pallas_interpret_maxerr={err:.1e}"


def bench_fig9_roofline_serving(model, params, val, full_acc):
    """Figs 9-12 analog: TPU-roofline FPS/energy of quantized vs binarized
    policies (replaces the paper's FPGA measurements; DESIGN.md section 3)."""
    from repro.core.roofline import TPURoofline
    from repro.quant.policy import QuantMode, QuantPolicy
    graph = model.graph()
    rl = TPURoofline()
    t0 = time.time()
    rows = {}
    for name, bits in (("Q8", 8), ("Q4", 4), ("B4", 4), ("F", 16)):
        mode = QuantMode.BINARIZE if name.startswith("B") else QuantMode.QUANT
        p = QuantPolicy.uniform(graph, float(bits), mode=mode)
        rows[name] = (rl.throughput_fps(graph, p), rl.energy(graph, p))
    us = (time.time() - t0) / len(rows) * 1e6
    derived = "_".join(f"{k}:fps={v[0]:.2e}:J={v[1]:.2e}"
                       for k, v in rows.items())
    return us, derived


def bench_dryrun_roofline(*_):
    """Roofline section: summarize results/roofline.json."""
    f = pathlib.Path("results/roofline.json")
    if not f.exists():
        return 0.0, "run_launch.roofline_first"
    rows = json.loads(f.read_text())
    t0 = time.time()
    doms = {}
    for c in rows:
        doms[c["dominant"]] = doms.get(c["dominant"], 0) + 1
    us = (time.time() - t0) * 1e6
    derived = "_".join(f"{k}:{v}" for k, v in sorted(doms.items())) + \
        f"_cells={len(rows)}"
    return us, derived


BENCHES = [
    ("table2_quant_episode", bench_table2_quant, True),
    ("table3_binarize_episode", bench_table3_binarize, True),
    ("table4_compare_eval", bench_table4_compare, True),
    ("fig8_hier_vs_flat_episode", bench_fig8_convergence, True),
    ("fig9_roofline_serving", bench_fig9_roofline_serving, True),
    ("kernel_quant_matmul", bench_kernel_quant_matmul, False),
    ("kernel_binary_matmul", bench_kernel_binary_matmul, False),
    ("kernel_fake_quant", bench_kernel_fake_quant, False),
    ("dryrun_roofline_summary", bench_dryrun_roofline, False),
]


def main() -> None:
    print("name,us_per_call,derived")
    ctx = None
    for name, fn, needs_sub in BENCHES:
        if needs_sub and ctx is None:
            ctx = _substrate()
        try:
            us, derived = fn(*(ctx if needs_sub else ()))
            print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:                      # pragma: no cover
            print(f"{name},nan,ERROR:{e!r}", flush=True)


if __name__ == '__main__':
    main()
