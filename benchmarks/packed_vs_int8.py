"""Weight-store benchmark: int8 vs bit-packed sub-byte serving path.

Compares, for an AutoQ-style mixed-QBN policy (~4-bit average, the regime
the paper's searches land in):

* weight-side HBM bytes of the int8 store (kernels/quant_matmul.py path)
  vs the bucketed packed store (kernels/pack.py + quant_pack_sub8);
* wall-clock of the two matmul paths -- interpret mode on CPU (numerics
  validation), compiled on TPU (the real roofline comparison, where the
  packed path's smaller weight reads are the win the reward model prices).

Usage:  PYTHONPATH=src python benchmarks/packed_vs_int8.py [--m 256]
        [--k 2048] [--n 2048] [--iters 5]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.quant import quant_pack_int8, quant_pack_sub8

# a 4-bit-average kernel-wise mixture (most channels 2-4 bits, a tail at
# 6/8 -- the shape AutoQ's searched policies take on CNNs/LMs)
POLICY_MIX = [2, 3, 4, 4, 4, 4, 6, 8, 2, 3]


def _mixed_bits(n: int) -> np.ndarray:
    reps = int(np.ceil(n / len(POLICY_MIX)))
    return np.asarray((POLICY_MIX * reps)[:n], np.float32)


def _time(fn, iters: int) -> float:
    fn()                                     # compile / warm caches
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--k", type=int, default=2048)
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args()
    M, K, N = args.m, args.k, args.n

    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    bits = _mixed_bits(N)
    avg_bits = float(bits.mean())

    qi, si, _ = quant_pack_int8(w, bits, axis=1)
    pw = quant_pack_sub8(w, bits)

    int8_bytes = qi.size * qi.dtype.itemsize + si.size * si.dtype.itemsize
    packed_bytes = pw.hbm_bytes()
    print(f"shape ({M}, {K}) @ ({K}, {N}), avg QBN {avg_bits:.2f}")
    print(f"weight HBM bytes  int8 store   : {int8_bytes:>12,}")
    print(f"weight HBM bytes  packed store : {packed_bytes:>12,}"
          f"   ({100.0 * packed_bytes / int8_bytes:.1f}% of int8)")
    for name, nbytes in pw.bucket_nbytes().items():
        print(f"    bucket {name:<6}: {nbytes:>12,} B")

    mode = "interpret (CPU)" if ops.INTERPRET else "compiled (TPU)"
    t_i8 = _time(lambda: ops.quant_matmul(x, qi, si.reshape(-1)), args.iters)
    t_pk = _time(lambda: ops.packed_mixed_matmul(x, pw), args.iters)
    print(f"wall-clock [{mode}]  int8 matmul  : {t_i8 * 1e3:8.2f} ms")
    print(f"wall-clock [{mode}]  packed matmul: {t_pk * 1e3:8.2f} ms")

    y_i8 = ops.quant_matmul(x, qi, si.reshape(-1), use_pallas=False)
    y_pk = ops.packed_mixed_matmul(x, pw, use_pallas=False)
    # both stores quantize b<=8 channels on the same grid -> same result
    err = float(jnp.max(jnp.abs(y_i8 - y_pk)))
    print(f"max |int8 - packed| over outputs: {err:.2e}")
    if K >= 64:
        assert packed_bytes <= 0.60 * int8_bytes, \
            (packed_bytes, int8_bytes, "packed store must be <= 60% of int8")
    else:
        # per-channel f32 scales (4 B, paid by both stores) only amortize
        # once K is large; the <=60% guarantee is about the weight bytes
        print(f"note: K={K} too small for the <=60% bytes check "
              "(scale overhead dominates)")


if __name__ == "__main__":
    main()
