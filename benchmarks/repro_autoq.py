"""Faithful-reproduction experiment driver (EXPERIMENTS.md §Repro-*).

Re-creates the paper's tables/figures on the offline substrate (synthetic
CIFAR-like data; see DESIGN.md §3 "assumptions changed"):

  table2  -- network quantization:  F / N / L / C  x  {RC, AG}   (Table 2)
  table3  -- network binarization:  same grid                     (Table 3)
  fig8    -- hierarchical vs flat-channel DDPG convergence        (Fig. 8)
  table4  -- cost-at-iso-accuracy vs layer-level (HAQ-like) DDPG  (Table 4)
  fig7    -- NetScore- vs FLOP-based reward, last-layer bits      (Fig. 5/7)
  lm      -- kernel-wise search on tiny LM configs (beyond-paper: the
             assigned-architecture families)

Run everything:   PYTHONPATH=src python -m benchmarks.repro_autoq --full
Fast smoke (CI):  PYTHONPATH=src python -m benchmarks.repro_autoq
Writes results/repro/<name>.json.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core import (FlatAgent, HierarchicalAgent, LayerBounder, QuantEnv,
                        RewardCfg, make_cnn_evaluator, make_lm_evaluator,
                        run_search)
from repro.core.ddpg import adam_init, adam_update
from repro.data import SyntheticImages, TokenStream
from repro.models import LM
from repro.models.cnn import CNN, CIF10_TINY
from repro.quant.policy import QuantMode, QuantPolicy
from repro.train.qat import qat_finetune

OUT = pathlib.Path("results/repro")
DATA = SyntheticImages(img_size=16)


# ----------------------------------------------------------------- substrate
def train_substrate(steps=250):
    model = CNN(CIF10_TINY)
    params = model.init(jax.random.PRNGKey(0))

    @jax.jit
    def step(p, o, b):
        l, g = jax.value_and_grad(model.loss)(p, b)
        p, o = adam_update(p, g, o, 2e-3)
        return p, o, l

    opt = adam_init(params)
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in DATA.batch(i, 128).items()}
        params, opt, _ = step(params, opt, b)
    val = DATA.batch(99_999, 512)
    acc = float(model.accuracy(
        params, {k: jnp.asarray(v) for k, v in val.items()})) * 100
    return model, params, val, acc


def _env(model, params, val, graph, mode, protocol, target_bits=5.0):
    ev = make_cnn_evaluator(model, params, graph, val, mode=mode)
    if protocol == "rc":
        reward = RewardCfg.resource_constrained()
        bounder = LayerBounder(graph, target_bits, target_bits)
    elif protocol == "ag":
        reward, bounder = RewardCfg.accuracy_guaranteed(), None
    else:  # "flop" (section 4.3)
        reward, bounder = RewardCfg.flop_based(), None
    return QuantEnv(graph, params, ev, reward, mode=mode, bounder=bounder), ev


def _finetuned_acc(model, params, graph, policy, val, mode, steps):
    if steps == 0:
        return None
    tuned = qat_finetune(model, params, graph, policy,
                         lambda i: DATA.batch(50_000 + i, 128), steps=steps)
    ev = make_cnn_evaluator(model, tuned, graph, val, mode=mode)
    return float(ev(policy))


# ------------------------------------------------------------ tables 2 and 3
def run_table(mode: QuantMode, model, params, val, full_acc,
              episodes=(60, 190), qat_steps=60, seed=0):
    graph = model.graph()
    rows = []
    t0 = time.time()
    _, ev = _env(model, params, val, graph, mode, "ag")

    rows.append({"scheme": "F", "protocol": "-", "top1": full_acc,
                 "act_bits": 32.0, "wei_bits": 32.0, "logic_ratio": 1.0})
    for protocol in ("rc", "ag"):
        # N: empirical uniform policy (5-bit, the paper's baseline)
        p5 = QuantPolicy.uniform(graph, 5.0, mode=mode)
        rows.append({"scheme": "N", "protocol": protocol, "top1": ev(p5),
                     "top1_ft": _finetuned_acc(model, params, graph, p5, val,
                                               mode, qat_steps),
                     "act_bits": 5.0, "wei_bits": 5.0,
                     "logic_ratio": p5.logic_ops(graph) /
                     (graph.total_macs * 1024)})
        # L: layer-level flat DDPG (HAQ-like)
        env, ev2 = _env(model, params, val, graph, mode, protocol)
        agent = FlatAgent(env, seed=seed, granularity="layer")
        res = run_search(agent, *episodes)
        pl = res.best_policy
        rows.append({"scheme": "L", "protocol": protocol,
                     "top1": res.best_log.acc,
                     "top1_ft": _finetuned_acc(model, params, graph, pl, val,
                                               mode, qat_steps),
                     "act_bits": res.best_log.avg_abits,
                     "wei_bits": res.best_log.avg_wbits,
                     "logic_ratio": res.best_log.logic_ratio,
                     "episodes": sum(episodes), "wall_s": res.wall_s})
        # C: kernel-wise hierarchical DRL (the paper)
        env, ev2 = _env(model, params, val, graph, mode, protocol)
        agent = HierarchicalAgent(env, seed=seed)
        res = run_search(agent, *episodes)
        pc = res.best_policy
        rows.append({"scheme": "C", "protocol": protocol,
                     "top1": res.best_log.acc,
                     "top1_ft": _finetuned_acc(model, params, graph, pc, val,
                                               mode, qat_steps),
                     "act_bits": res.best_log.avg_abits,
                     "wei_bits": res.best_log.avg_wbits,
                     "logic_ratio": res.best_log.logic_ratio,
                     "episodes": sum(episodes), "wall_s": res.wall_s,
                     "per_layer_wbits": {
                         l.name: float(np.mean(pc.weight_bits[l.name]))
                         for l in graph.layers}})
    return {"mode": mode.value, "full_acc": full_acc, "rows": rows,
            "wall_s": time.time() - t0}


# ------------------------------------------------------------------- figure 8
def run_fig8(model, params, val, episodes=250, seed=0):
    graph = model.graph()
    out = {}
    for name, mk in (("hierarchical",
                      lambda e: HierarchicalAgent(e, seed=seed)),
                     ("flat_ddpg",
                      lambda e: FlatAgent(e, seed=seed,
                                          granularity="channel"))):
        env, _ = _env(model, params, val, graph, QuantMode.QUANT, "ag")
        res = run_search(mk(env), n_explore=episodes // 4,
                         n_exploit=episodes - episodes // 4)
        out[name] = {"acc_curve": res.acc_curve(),
                     "reward_curve": res.reward_curve(),
                     "best_acc": res.best_log.acc, "wall_s": res.wall_s}
    return out


# ------------------------------------------------------------------- table 4
def run_table4(t2):
    """Cost at iso-accuracy: C (AutoQ) vs L (HAQ-like), from table2 rows."""
    rows = {r["scheme"] + "/" + r["protocol"]: r for r in t2["rows"]}
    c, l = rows.get("C/ag"), rows.get("L/ag")
    return {
        "autoq_channel": {"d_top1": c["top1_ft"] - t2["full_acc"],
                          "norm_logic": c["logic_ratio"]},
        "haq_like_layer": {"d_top1": l["top1_ft"] - t2["full_acc"],
                           "norm_logic": l["logic_ratio"]},
    }


# ------------------------------------------------------------------- figure 7
def run_fig7(model, params, val, episodes=(40, 120), seed=0):
    """NetScore vs FLOP-based reward: the FLOP reward has no incentive to
    shrink the FC layer's weights (paper section 4.3)."""
    graph = model.graph()
    out = {}
    for name, protocol in (("netscore", "ag"), ("flop", "flop")):
        env, _ = _env(model, params, val, graph, QuantMode.QUANT, protocol)
        agent = HierarchicalAgent(env, seed=seed)
        res = run_search(agent, *episodes)
        p = res.best_policy
        out[name] = {
            "per_layer_wbits": {l.name: float(np.mean(p.weight_bits[l.name]))
                                for l in graph.layers},
            "fc_wbits": float(np.mean(p.weight_bits["fc"])),
            "acc": res.best_log.acc,
            "logic_ratio": res.best_log.logic_ratio,
        }
    return out


# ---------------------------------------------------------------------- LMs
def run_lm(arch_id="phi4-mini-3.8b", episodes=(30, 90), seed=0):
    cfg = ARCHS[arch_id].smoke
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stream = TokenStream(vocab=cfg.vocab)

    @jax.jit
    def step(p, o, b):
        l, g = jax.value_and_grad(model.loss)(p, b)
        p, o = adam_update(p, g, o, 2e-3)
        return p, o, l

    opt = adam_init(params)
    for i in range(150):
        b = {k: jnp.asarray(v) for k, v in stream.batch(i, 16, 32).items()}
        params, opt, _ = step(params, opt, b)
    val = stream.batch(99_999, 32, 32)
    graph = model.graph(seq_len=32, batch=32, max_groups=16)
    ev = make_lm_evaluator(model, params, graph, val)
    full_acc = ev(QuantPolicy.uniform(graph, 32.0))
    u5 = ev(QuantPolicy.uniform(graph, 5.0))

    env = QuantEnv(graph, params, ev, RewardCfg.accuracy_guaranteed())
    agent = HierarchicalAgent(env, seed=seed)
    res = run_search(agent, *episodes)
    return {"arch": arch_id, "full_acc": full_acc, "uniform5_acc": u5,
            "searched_acc": res.best_log.acc,
            "avg_wbits": res.best_log.avg_wbits,
            "avg_abits": res.best_log.avg_abits,
            "logic_ratio": res.best_log.logic_ratio,
            "episodes": sum(episodes), "wall_s": res.wall_s}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    OUT.mkdir(parents=True, exist_ok=True)

    episodes = (60, 190) if args.full else (3, 5)
    fig8_eps = 250 if args.full else 8
    qat_steps = 60 if args.full else 5
    train_steps = 250 if args.full else 60

    t0 = time.time()
    model, params, val, full_acc = train_substrate(train_steps)
    print(f"substrate CNN acc={full_acc:.1f}% ({time.time()-t0:.0f}s)",
          flush=True)

    def do(name, fn):
        if args.only and args.only != name:
            return
        t = time.time()
        out = fn()
        (OUT / f"{name}.json").write_text(json.dumps(out, indent=1))
        print(f"[{name}] done in {time.time()-t:.0f}s", flush=True)

    do("table2_quant", lambda: run_table(QuantMode.QUANT, model, params, val,
                                         full_acc, episodes, qat_steps))
    do("table3_binarize", lambda: run_table(QuantMode.BINARIZE, model, params,
                                            val, full_acc, episodes,
                                            qat_steps))
    do("fig8_convergence", lambda: run_fig8(model, params, val, fig8_eps))
    if (OUT / "table2_quant.json").exists():
        do("table4_compare", lambda: run_table4(
            json.loads((OUT / "table2_quant.json").read_text())))
    do("fig7_flop_reward", lambda: run_fig7(model, params, val,
                                            ((40, 120) if args.full
                                             else (3, 5))))
    do("lm_phi4", lambda: run_lm("phi4-mini-3.8b",
                                 (30, 90) if args.full else (2, 3)))
    do("lm_mamba2", lambda: run_lm("mamba2-780m",
                                   (30, 90) if args.full else (2, 3)))
    print(f"total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
