"""Continuous-batching benchmark: chunked vs monolithic prefill vs serial.

Serves a long-prompt + short-decode request mix three ways on one model:

* **serial** -- one ``generate`` call per request, back to back: the
  single-batch engine, each request paying a full decode loop alone;
* **monolithic** -- ``run(prefill="monolithic")``: continuous batching with
  the legacy admission (one batch-1 full-prompt prefill per request, which
  stalls every in-flight decode lane and compiles one prefill variant per
  prompt length);
* **chunked** -- ``run(prefill="chunked")``: the unified token-budget step
  loop -- prefill chunks and decode tokens share one jit'd ``model_step``
  per iteration, writing K/V straight into block-table pages.

``--speculative`` adds the multi-token-decode comparison
(docs/speculative.md): ``run(speculative=True)`` at ``draft_k`` in
{2, 4, 8} (smoke: {2, 4}), each with the full-depth *self-agreeing* draft
(``draft_layers = n_repeat``: the draft IS the target, acceptance 1.0 --
the mechanical ceiling) and the default shallow-prefix draft, reporting
acceptance rate, accepted-tokens/lane-step, and tok/s vs plain chunked
decode.

Reported per mode: per-request TTFT P50/P99 (wall seconds, including each
mode's own jit compiles -- the per-length variant explosion *is* the
monolithic TTFT pathology), aggregate tok/s over the whole run, decode
tok/s, and jit trace counts per engine entry point.

Acceptance gates (asserted):

* all three modes emit identical greedy token streams per request;
* chunked P99 TTFT beats monolithic P99 TTFT on the mixed workload at
  equal-or-better aggregate tok/s, and warm chunked steady-state decode
  beats serial decode (full mode only; smoke skips the timing-noise-
  sensitive throughput gates);
* chunked jit trace count is independent of the number of distinct prompt
  lengths (at most two ``model_step`` variants -- mixed-step and
  pure-decode; the batch-1 prefill path is never traced);
* with ``--speculative``: every speculative stream bit-equals the serial
  oracle, the self-agreeing draft accepts 100% of its proposals at
  accepted-tokens/lane-step > 1 (ceiling draft_k + 1: model calls per
  emitted token drop by that factor), and speculative runs stay within
  the bounded jit-variant budget (2 model_step + 2 draft_step).

Timing uses the jnp ``ref`` attention backend by default: off-TPU the
Pallas kernels run in interpret mode, whose per-grid-cell overhead scales
with page count and would distort the engine-level comparison (kernel
parity/perf gates live in benchmarks/attention.py; engine-level
pallas-vs-ref stream identity is pinned in tests/test_paged_kv.py).

Usage:  PYTHONPATH=src python benchmarks/continuous_batching.py
            [--requests 8] [--n-new 32] [--d-model 128] [--page-size 16]
            [--chunk CHUNK] [--attn-impl ref|pallas] [--speculative]
            [--draft-k K ...] [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import LM
from repro.serve import ServeEngine


def _workload(n_requests: int, n_new: int, vocab: int, max_len: int,
              seed: int = 0):
    """Long-prompt + short-decode mix, shorts queued behind longs.

    Every 4th request is a long prompt near ``max_len - n_new``; the rest
    are short, with *distinct* lengths (each distinct length is one more
    jit variant for the monolithic path).  Submit order interleaves them so
    short requests sit behind long prefills -- the head-of-line pattern
    chunked prefill exists to fix.
    """
    rng = np.random.default_rng(seed)
    cap = max_len - n_new                  # longest legal prompt
    if cap < 1:
        raise ValueError(f"max_len={max_len} leaves no room for a prompt "
                         f"before n_new={n_new} generated tokens")
    reqs = []
    for i in range(n_requests):
        if i % 4 == 0:                     # long: top half of the range
            s = cap - (i // 4) % max(1, cap // 2)
        else:                              # short, distinct until they wrap
            s = 1 + (3 + i) % cap
        reqs.append((rng.integers(0, vocab, size=int(s)).astype(np.int32),
                     n_new))
    return reqs


def _agg_tok_per_s(st) -> float:
    total_s = st.prefill_s + st.decode_s
    return st.tokens_out / total_s if total_s else 0.0


def _report(name: str, st) -> None:
    pct = st.ttft_percentiles()
    print(f"{name:11s}: {st.tokens_out:4d} tok, "
          f"TTFT P50 {pct[50] * 1e3:8.1f}ms  P99 {pct[99] * 1e3:8.1f}ms, "
          f"aggregate {_agg_tok_per_s(st):8.1f} tok/s, "
          f"decode {st.decode_tok_per_s:8.1f} tok/s  ({st.steps} steps)")


def _speculative_section(model, params, args, reqs, ser_outputs,
                         plain_st) -> None:
    """run(speculative=True) sweep + parity / acceptance-ceiling gates."""
    from repro.serve import ServeEngine
    ks = args.draft_k or ([2, 4] if args.smoke else [2, 4, 8])
    n_rep = model.cfg.n_repeat
    print(f"-- speculative decode (plain chunked decode "
          f"{plain_st.decode_tok_per_s:.1f} tok/s) --")
    for k in ks:
        # self-agree: draft == target, acceptance 1.0 -- the mechanical
        # ceiling (k+1 tokens per verify); prefix-half: the default
        # shallow self-draft, the realistic acceptance point
        for label, kw in (("self-agree", {"draft_layers": n_rep}),
                          ("prefix-half", {})):
            eng = ServeEngine(model, params, max_len=args.max_len,
                              attn_impl=args.attn_impl)
            res = eng.run(reqs, page_size=args.page_size,
                          max_slots=args.requests, prefill="chunked",
                          chunk_tokens=args.chunk, speculative=True,
                          draft_k=k, **kw)
            st = res["stats"]
            print(f"spec k={k} {label:11s}: acc {st.acceptance_rate:5.2f}, "
                  f"{st.spec_tokens_per_step:5.2f} tok/lane-step, "
                  f"aggregate {_agg_tok_per_s(st):8.1f} tok/s, decode "
                  f"{st.decode_tok_per_s:8.1f} tok/s ({st.steps} steps)")
            for i, (ref, got) in enumerate(zip(ser_outputs, res["outputs"])):
                np.testing.assert_array_equal(
                    got, ref, err_msg=f"speculative k={k} {label}: request "
                                      f"{i} diverged from generate")
            assert eng.trace_counts["model_step"] <= 2 and \
                eng.trace_counts["draft_step"] <= 2, dict(eng.trace_counts)
            if label == "self-agree":
                assert st.acceptance_rate == 1.0, (
                    "a draft that IS the target must have every proposal "
                    "accepted", st.acceptance_rate)
                assert 1.0 < st.spec_tokens_per_step <= k + 1, (
                    "accepted-tokens/lane-step must beat plain decode's "
                    "1.0 and respect the draft_k+1 ceiling",
                    st.spec_tokens_per_step)
    print("OK: speculative parity + acceptance-ceiling gates passed")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--n-new", type=int, default=32)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=None,
                    help="chunk_tokens for the chunked mode (default: "
                         "page_size)")
    ap.add_argument("--attn-impl", choices=("ref", "pallas"), default="ref",
                    help="attention backend to time (default ref: off-TPU "
                         "the Pallas kernels run in interpret mode, whose "
                         "per-grid-cell overhead distorts engine wall-clock"
                         " -- kernel-level timing lives in "
                         "benchmarks/attention.py)")
    ap.add_argument("--speculative", action="store_true",
                    help="also run speculative multi-token decode at each "
                         "--draft-k, with parity + acceptance-ceiling gates"
                         " (docs/speculative.md)")
    ap.add_argument("--draft-k", type=int, nargs="*", default=None,
                    help="draft_k values for --speculative (default 2 4 8; "
                         "smoke: 2 4)")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run: parity + TTFT + trace gates only "
                         "(CI); skips the timing-sensitive throughput gate")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.n_new = min(args.requests, 8), 6
        args.d_model, args.max_len, args.page_size = 64, 48, 4

    cfg = dataclasses.replace(ARCHS["internlm2-20b"].smoke,
                              d_model=args.d_model, d_ff=4 * args.d_model)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _workload(args.requests, args.n_new, cfg.vocab, args.max_len)
    print(f"workload: {args.requests} requests, prompts "
          f"{[int(t.size) for t, _ in reqs]}, {args.n_new} new tokens each, "
          f"d_model={cfg.d_model}, page_size={args.page_size}")

    # serial baseline and the warm-cache decode-rate comparison: one engine,
    # jit warmed first so both paths are measured compiled (the cold-start
    # cost is measured separately below, where it is the story)
    eng = ServeEngine(model, params, max_len=args.max_len,
                      attn_impl=args.attn_impl)
    eng.generate(reqs[0][0][None], 2)
    eng.run(reqs[:1], page_size=args.page_size, max_slots=args.requests,
            prefill="chunked", chunk_tokens=args.chunk)
    ser_outputs, ser_decode_s, ser_toks = [], 0.0, 0
    for toks, n_new in reqs:
        out = eng.generate(toks[None], n_new)
        ser_outputs.append(out["tokens"][0])
        ser_decode_s += out["stats"].decode_s
        ser_toks += out["stats"].tokens_out
    serial_tps = ser_toks / ser_decode_s
    warm_chunked = eng.run(reqs, page_size=args.page_size,
                           max_slots=args.requests, prefill="chunked",
                           chunk_tokens=args.chunk)["stats"]

    # fresh engine per mode: each pays its own jit variants, which is the
    # serving cost under comparison
    runs = {}
    for mode in ("monolithic", "chunked"):
        e = ServeEngine(model, params, max_len=args.max_len,
                        attn_impl=args.attn_impl)
        kw = {"chunk_tokens": args.chunk} if mode == "chunked" else {}
        runs[mode] = (e, e.run(reqs, page_size=args.page_size,
                               max_slots=args.requests, prefill=mode, **kw))

    print(f"serial     : {ser_toks:4d} tok in {ser_decode_s:6.2f}s decode "
          f"-> {serial_tps:8.1f} decode tok/s (warm)")
    print(f"chunked    : warm decode {warm_chunked.decode_tok_per_s:8.1f} "
          f"tok/s ({warm_chunked.steps} steps) -- cold runs below")
    for mode, (e, res) in runs.items():
        _report(mode, res["stats"])
        print(f"             jit traces: {dict(e.trace_counts)}")

    # ---- gates ----------------------------------------------------------
    for mode, (_, res) in runs.items():            # stream parity, per mode
        for i, (ref, got) in enumerate(zip(ser_outputs, res["outputs"])):
            np.testing.assert_array_equal(
                got, ref, err_msg=f"{mode}: request {i} diverged from "
                                  "independent generate")
    mono_st = runs["monolithic"][1]["stats"]
    chnk_st = runs["chunked"][1]["stats"]
    chnk_eng = runs["chunked"][0]
    assert chnk_eng.trace_counts["model_step"] <= 2, (
        "chunked loop compiles at most two model_step variants (mixed-step "
        "and pure-decode), independent of prompt lengths",
        dict(chnk_eng.trace_counts))
    assert chnk_eng.trace_counts.get("prefill", 0) == 0, \
        "chunked loop must never touch the batch-1 prefill path"
    p99_mono = mono_st.ttft_percentiles()[99]
    p99_chnk = chnk_st.ttft_percentiles()[99]
    print(f"P99 TTFT    : {p99_chnk * 1e3:.1f}ms chunked vs "
          f"{p99_mono * 1e3:.1f}ms monolithic "
          f"({p99_mono / max(p99_chnk, 1e-9):.2f}x better)")
    assert p99_chnk < p99_mono, (
        "chunked prefill must improve P99 TTFT on the long-prompt mix",
        p99_chnk, p99_mono)
    if not args.smoke:
        agg_c, agg_m = _agg_tok_per_s(chnk_st), _agg_tok_per_s(mono_st)
        # note: per-mode decode_tok_per_s is not comparable across modes --
        # chunked's decode time absorbs mixed-step chunk work (conservative)
        # while monolithic's prefill stalls are timed as prefill; aggregate
        # tok/s over the whole run is the like-for-like throughput metric
        assert agg_c >= agg_m, (
            "chunked prefill must hold aggregate throughput",
            agg_c, agg_m)
        assert warm_chunked.decode_tok_per_s > serial_tps, (
            "continuous batching must beat serial decode throughput",
            warm_chunked.decode_tok_per_s, serial_tps)
    print("OK: parity + TTFT + trace gates passed"
          + ("" if args.smoke else " (+ throughput gates)"))
    if args.speculative:
        _speculative_section(model, params, args, reqs, ser_outputs,
                             chnk_st)


if __name__ == "__main__":
    main()
