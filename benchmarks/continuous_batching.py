"""Continuous-batching benchmark: serial vs interleaved decode throughput.

Serves the same mixed-length request workload two ways on one engine:

* **serial** -- one ``generate`` call per request, back to back: the
  single-batch engine, each request paying a full decode loop alone;
* **interleaved** -- one ``ServeEngine.run`` call: all requests admitted
  into the paged decode batch, one fused ``decode_step_paged`` advancing
  every in-flight sequence per step.

The interleaved path amortizes the per-step weight read (the HBM term the
AutoQ roofline reward prices) over every in-flight sequence, so aggregate
decode tok/s must beat the serial path -- that inequality is asserted, it
is the acceptance criterion for the continuous-batching engine.

Usage:  PYTHONPATH=src python benchmarks/continuous_batching.py
            [--requests 8] [--n-new 32] [--d-model 128] [--page-size 16]
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import LM
from repro.serve import ServeEngine


def _workload(n_requests: int, n_new: int, vocab: int, max_len: int,
              seed: int = 0):
    """Mixed prompt lengths spread over [4, max_len - n_new]."""
    rng = np.random.default_rng(seed)
    lens = np.linspace(4, max_len - n_new, n_requests).astype(int)
    return [(rng.integers(0, vocab, size=int(s)).astype(np.int32), n_new)
            for s in lens]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--n-new", type=int, default=32)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    cfg = dataclasses.replace(ARCHS["internlm2-20b"].smoke,
                              d_model=args.d_model, d_ff=4 * args.d_model)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_len=args.max_len)
    reqs = _workload(args.requests, args.n_new, cfg.vocab, args.max_len)

    # warm the jit caches so both paths are measured compiled
    eng.generate(reqs[0][0][None], 2)
    eng.run(reqs[:1], page_size=args.page_size, max_slots=args.requests)

    ser_decode_s, ser_toks = 0.0, 0
    for toks, n_new in reqs:
        out = eng.generate(toks[None], n_new)
        ser_decode_s += out["stats"].decode_s
        ser_toks += out["stats"].tokens_out
    serial_tps = ser_toks / ser_decode_s

    res = eng.run(reqs, page_size=args.page_size, max_slots=args.requests)
    st = res["stats"]
    inter_toks = st.tokens_out - st.prefill_tokens
    inter_tps = st.decode_tok_per_s

    print(f"workload: {args.requests} requests, prompts "
          f"{[int(t.size) for t, _ in reqs]}, {args.n_new} new tokens each, "
          f"d_model={cfg.d_model}")
    print(f"serial      : {ser_toks:4d} tok in {ser_decode_s:6.2f}s decode "
          f"-> {serial_tps:8.1f} tok/s")
    print(f"interleaved : {inter_toks:4d} tok in {st.decode_s:6.2f}s decode "
          f"-> {inter_tps:8.1f} tok/s   ({st.steps} batched steps)")
    print(f"speedup     : {inter_tps / serial_tps:5.2f}x aggregate decode "
          "throughput")
    assert inter_tps > serial_tps, (
        "continuous batching must beat serial decode throughput",
        inter_tps, serial_tps)


if __name__ == "__main__":
    main()
