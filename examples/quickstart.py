"""Quickstart: kernel-wise quantization search in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py

Trains a small CNN on synthetic data, runs a short AutoQ hierarchical-DRL
search (accuracy-guaranteed protocol), and prints the discovered per-channel
bit-width policy.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (HierarchicalAgent, QuantEnv, RewardCfg,
                        make_cnn_evaluator, run_search)
from repro.core.ddpg import adam_init, adam_update
from repro.data import SyntheticImages
from repro.models.cnn import CNN, CIF10_TINY


def main():
    print("== 1. train the substrate CNN ==")
    model = CNN(CIF10_TINY)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticImages(img_size=16)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(model.loss)(p, b)
        p, o = adam_update(p, g, o, 2e-3)
        return p, o, loss

    opt = adam_init(params)
    for i in range(150):
        b = {k: jnp.asarray(v) for k, v in data.batch(i, 128).items()}
        params, opt, loss = step(params, opt, b)
    val = data.batch(99_999, 512)
    acc = float(model.accuracy(
        params, {k: jnp.asarray(v) for k, v in val.items()})) * 100
    print(f"   full-precision accuracy: {acc:.1f}%")

    print("== 2. AutoQ kernel-wise search (accuracy-guaranteed) ==")
    graph = model.graph()
    ev = make_cnn_evaluator(model, params, graph, val)
    env = QuantEnv(graph, params, ev, RewardCfg.accuracy_guaranteed())
    agent = HierarchicalAgent(env, seed=0)
    res = run_search(agent, n_explore=10, n_exploit=20,
                     callback=lambda ep, log: print(
                         f"   ep {ep:3d}: acc={log.acc:5.1f}% "
                         f"wbits={log.avg_wbits:4.2f} reward={log.reward:6.1f}")
                     if ep % 5 == 0 else None)

    print("== 3. best policy ==")
    best = res.best_policy
    print(f"   acc={res.best_log.acc:.1f}% (full {acc:.1f}%), "
          f"avg weight bits {res.best_log.avg_wbits:.2f}, "
          f"avg act bits {res.best_log.avg_abits:.2f}, "
          f"logic ratio {res.best_log.logic_ratio:.4f}")
    for layer in graph.layers:
        bits = best.weight_bits[layer.name]
        print(f"   {layer.name:8s} act={best.act_bits[layer.name]:4.1f}  "
              f"w-chan bits: {np.array2string(bits, precision=0)}")


if __name__ == "__main__":
    main()
