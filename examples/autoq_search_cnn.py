"""Configurable AutoQ search on the paper's CNN family.

    PYTHONPATH=src python examples/autoq_search_cnn.py \
        --mode quant --protocol ag --episodes 100 [--granularity channel]

Protocols: rc (resource-constrained, Algorithm-1 bounded, target 5 bits),
ag (accuracy-guaranteed), flop (AMC-style FLOP reward, section 4.3).
Granularity: channel (hierarchical DRL, the paper) / layer (HAQ-like flat) /
flat-channel (Fig. 8 baseline).
"""
import argparse
import json

import jax
import jax.numpy as jnp

from repro.core import (FlatAgent, HierarchicalAgent, LayerBounder, QuantEnv,
                        RewardCfg, make_cnn_evaluator, run_search)
from repro.core.ddpg import adam_init, adam_update
from repro.data import SyntheticImages
from repro.models.cnn import CNN, CIF10, CIF10_TINY
from repro.quant.policy import QuantMode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["quant", "binarize"], default="quant")
    ap.add_argument("--protocol", choices=["rc", "ag", "flop"], default="ag")
    ap.add_argument("--granularity", default="channel",
                    choices=["channel", "layer", "flat-channel"])
    ap.add_argument("--episodes", type=int, default=100)
    ap.add_argument("--target-bits", type=float, default=5.0)
    ap.add_argument("--big", action="store_true", help="use CIF10 (7 conv)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    model = CNN(CIF10 if args.big else CIF10_TINY)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticImages(img_size=model.cfg.img_size)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(model.loss)(p, b)
        p, o = adam_update(p, g, o, 2e-3)
        return p, o, loss

    opt = adam_init(params)
    for i in range(250):
        b = {k: jnp.asarray(v) for k, v in data.batch(i, 128).items()}
        params, opt, _ = step(params, opt, b)
    val = data.batch(99_999, 512)

    mode = QuantMode.QUANT if args.mode == "quant" else QuantMode.BINARIZE
    graph = model.graph()
    ev = make_cnn_evaluator(model, params, graph, val, mode=mode)
    reward = {"rc": RewardCfg.resource_constrained(),
              "ag": RewardCfg.accuracy_guaranteed(),
              "flop": RewardCfg.flop_based()}[args.protocol]
    bounder = (LayerBounder(graph, args.target_bits, args.target_bits)
               if args.protocol == "rc" else None)
    env = QuantEnv(graph, params, ev, reward, mode=mode, bounder=bounder)

    if args.granularity == "channel":
        agent = HierarchicalAgent(env, seed=args.seed)
    else:
        agent = FlatAgent(env, seed=args.seed,
                          granularity="layer" if args.granularity == "layer"
                          else "channel")
    res = run_search(agent, n_explore=args.episodes // 4,
                     n_exploit=args.episodes - args.episodes // 4,
                     callback=lambda ep, log: print(
                         f"ep {ep:3d} acc={log.acc:5.1f}% "
                         f"w={log.avg_wbits:4.2f} a={log.avg_abits:4.2f} "
                         f"r={log.reward:7.2f}", flush=True)
                     if ep % 10 == 0 else None)
    out = {"best_acc": res.best_log.acc, "avg_wbits": res.best_log.avg_wbits,
           "avg_abits": res.best_log.avg_abits,
           "logic_ratio": res.best_log.logic_ratio, "wall_s": res.wall_s}
    print(json.dumps(out, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f)


if __name__ == "__main__":
    main()
