"""End-to-end driver: train a small LM with the full production substrate
(Trainer: checkpoint/restart, deterministic data), run an AutoQ kernel-wise
search on it, then serve it quantized with batched requests.

    PYTHONPATH=src python examples/train_and_serve_lm.py [--steps 300]

This is the CPU-scale rehearsal of the cluster pipeline: the same model code,
sharding-spec machinery, Trainer, and ServeEngine lower unchanged against the
16x16 / 2x16x16 production meshes in the multi-pod dry-run.
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (HierarchicalAgent, QuantEnv, RewardCfg,
                        make_lm_evaluator, run_search)
from repro.data import TokenStream
from repro.models import LM
from repro.models.api import BlockDef, LMConfig
from repro.optim import AdamW
from repro.quant.policy import QuantPolicy
from repro.serve import ServeEngine
from repro.train import TrainConfig, Trainer

CFG = LMConfig(name="tiny-lm", d_model=128, n_heads=4, n_kv_heads=2,
               d_ff=384, vocab=256, n_layers=4,
               pattern=(BlockDef(kind="attn"),))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--episodes", type=int, default=40)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    stream = TokenStream(vocab=CFG.vocab)
    model = LM(CFG)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {CFG.name}, {n_params/1e6:.2f}M params")

    # ---- 1. fault-tolerant training ----
    ckpt_dir = tempfile.mkdtemp(prefix="tiny_lm_ckpt_")
    trainer = Trainer(
        model, params, AdamW(lr=2e-3),
        lambda s: stream.batch(s, args.batch, args.seq),
        ckpt_dir, TrainConfig(total_steps=args.steps, ckpt_every=100,
                              lr=2e-3, log_every=50))
    out = trainer.run()
    params = out["params"]
    for h in out["history"]:
        print(f"   step {h['step']:4d} loss {h['loss']:.3f}")

    # ---- 2. AutoQ kernel-wise search on the trained LM ----
    val = stream.batch(99_999, 32, args.seq)
    graph = model.graph(seq_len=args.seq, batch=32, max_groups=16)
    ev = make_lm_evaluator(model, params, graph, val)
    full_acc = ev(QuantPolicy.uniform(graph, 32.0))
    print(f"full-precision token accuracy: {full_acc:.1f}%")

    env = QuantEnv(graph, params, ev, RewardCfg.accuracy_guaranteed())
    agent = HierarchicalAgent(env, seed=0)
    res = run_search(agent, n_explore=args.episodes // 4,
                     n_exploit=args.episodes - args.episodes // 4)
    print(f"searched: acc={res.best_log.acc:.1f}% "
          f"avg_wbits={res.best_log.avg_wbits:.2f} "
          f"avg_abits={res.best_log.avg_abits:.2f} "
          f"logic_ratio={res.best_log.logic_ratio:.4f}")

    # ---- 3. quantized batched serving ----
    prompts = stream.batch(123, 8, 16)["tokens"]
    eng_fp = ServeEngine(model, params, max_len=64)
    eng_q = ServeEngine(model, params, policy=res.best_policy, graph=graph,
                        max_len=64)
    out_fp = eng_fp.generate(prompts, n_new=32)
    out_q = eng_q.generate(prompts, n_new=32)
    agree = (out_fp["tokens"] == out_q["tokens"]).mean()
    print(f"serving: fp {out_fp['stats'].decode_tok_per_s:.0f} tok/s | "
          f"quantized {out_q['stats'].decode_tok_per_s:.0f} tok/s | "
          f"greedy agreement {agree*100:.0f}%")


if __name__ == "__main__":
    main()
