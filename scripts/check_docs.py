#!/usr/bin/env python
"""Docs reachability check: every page in docs/ must be linked (transitively)
from docs/index.md, and every relative link must resolve to a real file.

Run via ``make docs-check``; CI runs it on every push.  Exit status is
non-zero on orphaned pages or broken links, with one line per finding.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

DOCS = Path(__file__).resolve().parent.parent / "docs"
INDEX = DOCS / "index.md"
# markdown inline links: [text](target); ignores external and anchor links
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")


def links_of(page: Path):
    for target in LINK_RE.findall(page.read_text(encoding="utf-8")):
        if "://" in target or target.startswith("mailto:"):
            continue
        yield target, (page.parent / target).resolve()


def main() -> int:
    if not INDEX.is_file():
        print(f"docs-check: missing landing page {INDEX}")
        return 1
    problems = []
    seen = {INDEX.resolve()}
    frontier = [INDEX]
    while frontier:
        page = frontier.pop()
        for raw, resolved in links_of(page):
            if not resolved.exists():
                problems.append(
                    f"broken link in {page.relative_to(DOCS.parent)}: "
                    f"({raw})")
            elif resolved.suffix == ".md" and resolved not in seen \
                    and DOCS in resolved.parents:
                seen.add(resolved)
                frontier.append(resolved)
    orphans = sorted(p for p in DOCS.rglob("*.md") if p.resolve() not in seen)
    problems += [f"orphaned page (unreachable from docs/index.md): "
                 f"{p.relative_to(DOCS.parent)}" for p in orphans]
    for msg in problems:
        print(f"docs-check: {msg}")
    if not problems:
        print(f"docs-check: OK ({len(seen)} pages reachable from index)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
