#!/usr/bin/env python
"""Docs hygiene gate: reachability, link targets, anchors, symbol rot.

Four checks over ``docs/`` (run via ``make docs-check``; CI runs it on
every push), each printing one line per finding and failing the build:

1. **Reachability** -- every page in docs/ must be linked (transitively)
   from docs/index.md; orphaned pages rot silently.
2. **Link targets** -- every relative link must resolve to a real file.
3. **Anchors** -- every intra-docs anchor (``page.md#section`` or
   ``#section``) must match a heading slug of the target page
   (GitHub-style slugification), so section cross-references cannot
   dangle after a heading rename.
4. **Symbol references** -- every backticked identifier-looking token
   (``snake_case``, ``CamelCase``, dotted paths like ``ServeEngine.run``)
   and every backticked file path must still exist in the source tree
   (grep-based: the token's words must appear in ``src/repro`` /
   ``tests`` / ``benchmarks`` / ``scripts``).  This is what keeps the
   module maps and deep dives from describing symbols that were renamed
   away.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = ROOT / "docs"
INDEX = DOCS / "index.md"
# markdown inline links: [text](target[#anchor])
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]*)(?:#([^)]*))?\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$", re.MULTILINE)
CODE_SPAN_RE = re.compile(r"`([^`\n]+)`")
# backticked tokens worth checking: identifiers, optionally dotted,
# optionally with a trailing () -- everything else (flags, shell lines,
# hyphenated labels, quoted literals) is skipped
IDENT_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*(?:\.[A-Za-z_][A-Za-z0-9_]*)*"
                      r"(?:\(\))?$")
CAMEL_RE = re.compile(r"^(?:[A-Z][a-z0-9]+){2,}$")
WORD_RE = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")
# external namespaces docs legitimately mention; their members are not ours
EXTERNAL = {"jax", "jnp", "np", "numpy", "lax", "pytest", "hypothesis",
            "python", "pip", "pallas", "functools", "dataclasses"}
# directories whose identifiers count as "exists" (docs reference test
# names and bench flags too, not only src/repro symbols)
SOURCE_DIRS = ("src/repro", "tests", "benchmarks", "scripts")


def github_slug(heading: str) -> str:
    """GitHub-style heading -> anchor slug."""
    text = re.sub(r"`([^`]*)`", r"\1", heading.strip())
    text = text.lower()
    text = re.sub(r"[^a-z0-9 _-]", "", text)
    return text.replace(" ", "-")


def anchors_of(page: Path) -> set:
    out = set()
    seen: dict = {}
    for _, heading in HEADING_RE.findall(page.read_text(encoding="utf-8")):
        slug = github_slug(heading)
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def source_words() -> set:
    """Every identifier appearing anywhere in the source tree."""
    words = set()
    for d in SOURCE_DIRS:
        for py in (ROOT / d).rglob("*.py"):
            words.update(WORD_RE.findall(py.read_text(encoding="utf-8")))
    words.update(WORD_RE.findall((ROOT / "Makefile").read_text()))
    return words


def path_exists(token: str) -> bool:
    """A backticked path reference must resolve somewhere sensible."""
    cand = token.rstrip("/")
    if any((base / cand).exists()
           for base in (ROOT, ROOT / "src", ROOT / "src" / "repro", DOCS)):
        return True
    if "/" not in cand:                # bare filename: search the tree
        name = Path(cand).name
        return any(next((ROOT / d).rglob(name), None) is not None
                   for d in SOURCE_DIRS + ("docs",))
    return False


def check_symbols(page: Path, words: set, problems: list) -> None:
    text = page.read_text(encoding="utf-8")
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)  # code blocks
    for token in CODE_SPAN_RE.findall(text):
        token = token.strip()
        if "/" in token or token.endswith((".py", ".md", ".ini", ".json",
                                           ".yml")):
            if re.fullmatch(r"[\w./-]+", token) and not path_exists(token):
                problems.append(f"stale path reference in "
                                f"{page.relative_to(ROOT)}: `{token}`")
            continue
        if not IDENT_RE.fullmatch(token):
            continue                    # flags, shell lines, literals, ...
        parts = token.removesuffix("()").split(".")
        if parts[0] in EXTERNAL:
            continue
        # only identifier-shaped tokens that plausibly name our symbols:
        # snake_case, CamelCase, or dotted -- single plain words are prose
        if len(parts) == 1 and "_" not in token and \
                not CAMEL_RE.fullmatch(parts[0]):
            continue
        missing = [p for p in parts if p not in words]
        if missing:
            problems.append(
                f"stale symbol reference in {page.relative_to(ROOT)}: "
                f"`{token}` ({', '.join(missing)} not found in "
                f"{'/'.join(SOURCE_DIRS)})")


def links_of(page: Path):
    for target, anchor in LINK_RE.findall(
            page.read_text(encoding="utf-8")):
        if "://" in target or target.startswith("mailto:"):
            continue
        resolved = (page.parent / target).resolve() if target \
            else page.resolve()
        yield target, anchor, resolved


def main() -> int:
    if not INDEX.is_file():
        print(f"docs-check: missing landing page {INDEX}")
        return 1
    problems: list = []
    words = source_words()
    seen = {INDEX.resolve()}
    frontier = [INDEX]
    while frontier:
        page = frontier.pop()
        check_symbols(page, words, problems)
        for raw, anchor, resolved in links_of(page):
            if not resolved.exists():
                problems.append(
                    f"broken link in {page.relative_to(ROOT)}: ({raw})")
                continue
            if anchor and resolved.suffix == ".md":
                if anchor not in anchors_of(resolved):
                    problems.append(
                        f"dangling anchor in {page.relative_to(ROOT)}: "
                        f"({raw or page.name}#{anchor}) -- no such heading "
                        f"in {resolved.name}")
            if resolved.suffix == ".md" and resolved not in seen \
                    and DOCS in resolved.parents:
                seen.add(resolved)
                frontier.append(resolved)
    orphans = sorted(p for p in DOCS.rglob("*.md") if p.resolve() not in seen)
    problems += [f"orphaned page (unreachable from docs/index.md): "
                 f"{p.relative_to(ROOT)}" for p in orphans]
    for msg in problems:
        print(f"docs-check: {msg}")
    if not problems:
        print(f"docs-check: OK ({len(seen)} pages reachable, anchors + "
              f"symbol references verified)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
